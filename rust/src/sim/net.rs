//! Simulated cluster network (DESIGN.md §5 substitution for the paper's
//! 1 Gbps Ethernet testbed).
//!
//! All PS traffic flows through a single router thread that models, per
//! directed (src, dst) link:
//!
//!   * propagation latency (+ optional uniform jitter),
//!   * serialization time `bytes / bandwidth` with the link busy until the
//!     message has fully "left the NIC" (messages queue behind each other);
//!     `bytes` is the *exact* encoded frame size from `transport::wire`,
//!     so this model and the real TCP framing agree byte-for-byte,
//!   * FIFO delivery (TCP-like; delivery times are made monotone per link).
//!
//! Intake is vectored, mirroring the TCP writer's coalesced batches: each
//! router wakeup drains everything queued (up to [`INTAKE_BATCH`], the
//! explicit coalescing boundary) into a reusable scratch buffer and
//! schedules the whole batch in one pass against a single arrival
//! timestamp. Coalescing changes neither determinism nor byte accounting:
//! messages are processed in intake (FIFO) order, so per-link clamps and
//! jitter rng draws happen in exactly the order they would one-at-a-time,
//! and every message is still charged its own exact frame size.
//!
//! Consistency-model behavior depends on the *ordering and delay* of
//! messages, not on physical NICs — this is exactly the phenomenon that
//! produces staleness, so it is the part we must reproduce faithfully.
//! With `NetConfig::instant()` the router forwards without delay, which is
//! what the pure-throughput benches use.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ps::msg::{ToShard, ToWorker};
use crate::sim::fault::FaultInjector;
use crate::telemetry::spans::{Mark, SpanRing};
use crate::util::hash::FxHashMap;
use crate::util::rng::Rng;

// The addressing and packet types live in the transport layer (shared
// with the real TCP backend); re-exported here for existing importers.
pub use crate::transport::{NodeId, Packet};

use crate::transport::PeerEvent;

/// Coalescing boundary of the router's vectored intake: at most this many
/// messages are drained and scheduled per wakeup before the loop returns
/// to dispatching due deliveries, so an intake flood cannot starve the
/// heap. Large enough that a full push wave or update fan-out coalesces
/// into one drain in practice.
const INTAKE_BATCH: usize = 256;

/// Link model parameters.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// One-way propagation latency.
    pub latency: Duration,
    /// Uniform jitter in [0, jitter] added per message.
    pub jitter: Duration,
    /// Link bandwidth in bytes/second (f64::INFINITY = no serialization
    /// delay). The paper's clusters use 1 Gbps; scaled-down defaults live
    /// in `config.rs`.
    pub bandwidth: f64,
    /// Seed for jitter.
    pub seed: u64,
}

impl NetConfig {
    /// Zero-delay network (throughput benches, unit tests).
    pub fn instant() -> Self {
        Self {
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            bandwidth: f64::INFINITY,
            seed: 0,
        }
    }

    /// A LAN-ish profile scaled for the single-machine testbed: the paper's
    /// 1 Gbps / ~0.1 ms Ethernet, with bandwidth scaled down so that
    /// comm:comp ratios at our (much smaller) workload sizes land in the
    /// same regime as the paper's cluster (see DESIGN.md §5).
    pub fn lan(seed: u64) -> Self {
        Self {
            latency: Duration::from_micros(200),
            jitter: Duration::from_micros(100),
            bandwidth: 40e6, // 40 MB/s
            seed,
        }
    }

    pub fn is_instant(&self) -> bool {
        self.latency.is_zero() && self.jitter.is_zero() && self.bandwidth.is_infinite()
    }
}

struct Wire {
    dst: NodeId,
    src: NodeId,
    packet: Packet,
}

/// Counters exposed for the comm/comp breakdown experiments.
#[derive(Debug, Default)]
pub struct NetStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
    pub delivered: AtomicU64,
    /// Span recorder for sampled frames (wire v9), installed once after
    /// construction via [`SimNet::set_spans`]; absent in untraced runs —
    /// the hot path then pays one `OnceLock` load and nothing else.
    spans: OnceLock<Arc<SpanRing>>,
}

/// Handle used by nodes to send through the simulated network.
#[derive(Clone)]
pub struct NetHandle {
    intake: Sender<Wire>,
    stats: Arc<NetStats>,
}

impl NetHandle {
    pub fn send(&self, src: NodeId, dst: NodeId, packet: Packet) {
        // AcqRel so `flush`'s Acquire reads observe these increments as
        // early as the memory model allows (see the note in `flush`).
        self.stats.messages.fetch_add(1, Ordering::AcqRel);
        self.stats
            .bytes
            .fetch_add(packet.wire_bytes() as u64, Ordering::AcqRel);
        // A sampled frame stamps its enqueue: the delivery side turns the
        // stamp into the in-transport `transport_flush` segment.
        if let Some(ring) = self.stats.spans.get() {
            if let Some(span) = packet.span() {
                let now = SpanRing::now_us();
                ring.record(span, "net", "transport_enqueue", now, 0);
                ring.mark(span.trace_id, Mark::Enqueue, now);
            }
        }
        // Ignore send errors during shutdown (router already gone).
        let _ = self.intake.send(Wire { src, dst, packet });
    }
}

impl crate::transport::Transport for NetHandle {
    fn send(&self, src: NodeId, dst: NodeId, packet: Packet) {
        NetHandle::send(self, src, dst, packet)
    }
}

/// The simulated network: owns the router thread.
pub struct SimNet {
    handle: NetHandle,
    router: Option<JoinHandle<()>>,
    stats: Arc<NetStats>,
}

impl SimNet {
    /// Build the network. `worker_inboxes[i]` / `shard_inboxes[i]` receive
    /// packets addressed to `NodeId::Worker(i)` / `NodeId::Shard(i)`.
    pub fn new(
        cfg: NetConfig,
        worker_inboxes: Vec<Sender<ToWorker>>,
        shard_inboxes: Vec<Sender<ToShard>>,
    ) -> Self {
        Self::with_faults(cfg, worker_inboxes, shard_inboxes, None)
    }

    /// Like [`SimNet::new`], with a fault injector evaluated against every
    /// packet at the router: `delay` adds to the scheduled delivery time,
    /// `drop` discards the packet (still counted settled, so `flush`
    /// terminates), `reorder` re-jitters it outside the FIFO clamp.
    pub fn with_faults(
        cfg: NetConfig,
        worker_inboxes: Vec<Sender<ToWorker>>,
        shard_inboxes: Vec<Sender<ToShard>>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Self {
        Self::with_control(cfg, worker_inboxes, shard_inboxes, faults, None, None)
    }

    /// Like [`SimNet::with_faults`], with the failover control plane
    /// attached: packets addressed to [`NodeId::Coordinator`] (heartbeat
    /// `StatsReport` replies) deliver into `coordinator` (dropped when
    /// absent — a run without a failure detector), and a delivery into a
    /// node whose inbox hung up (its thread died — a killed shard) emits
    /// one unclean [`PeerEvent::Disconnected`] per node on `events`, the
    /// sim's equivalent of the TCP reader's `peer_down`.
    pub fn with_control(
        cfg: NetConfig,
        worker_inboxes: Vec<Sender<ToWorker>>,
        shard_inboxes: Vec<Sender<ToShard>>,
        faults: Option<Arc<FaultInjector>>,
        coordinator: Option<Sender<ToWorker>>,
        events: Option<Sender<PeerEvent>>,
    ) -> Self {
        let (tx, rx) = channel::<Wire>();
        let stats = Arc::new(NetStats::default());
        let router_stats = stats.clone();
        let router = std::thread::Builder::new()
            .name("simnet-router".into())
            .spawn(move || {
                crate::sim::priority::infrastructure_thread();
                route_loop(
                    cfg,
                    rx,
                    worker_inboxes,
                    shard_inboxes,
                    coordinator,
                    events,
                    router_stats,
                    faults,
                )
            })
            .expect("spawn simnet router");
        SimNet {
            handle: NetHandle {
                intake: tx,
                stats: stats.clone(),
            },
            router: Some(router),
            stats,
        }
    }

    pub fn handle(&self) -> NetHandle {
        self.handle.clone()
    }

    /// Install the span recorder (wire v9). One-shot; a second call is
    /// ignored. Installed after construction so the widely-used
    /// constructors stay untouched.
    pub fn set_spans(&self, ring: Arc<SpanRing>) {
        let _ = self.stats.spans.set(ring);
    }

    pub fn messages(&self) -> u64 {
        self.stats.messages.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.stats.bytes.load(Ordering::Relaxed)
    }

    /// Block until every message sent so far has been delivered to its
    /// destination inbox. Used by the coordinator before issuing the
    /// direct-path Shutdown so no in-flight update is lost.
    pub fn flush(&self) {
        loop {
            // Read delivered BEFORE sent: delivered <= sent always holds
            // (every delivery is preceded by its send), so observing
            // delivered(t1) >= sent(t2) with t1 < t2 proves quiescence
            // for every send this thread can observe — in particular all
            // worker traffic, which happens-before flush via the worker
            // joins. (The opposite read order can return while messages
            // are still in flight even on x86.) The Shutdown that
            // follows flush races only shard->worker waves, which cannot
            // affect shard final state.
            let delivered = self.stats.delivered.load(Ordering::Acquire);
            let sent = self.stats.messages.load(Ordering::Acquire);
            if delivered >= sent {
                return;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// Stop the router after delivering everything still queued.
    pub fn shutdown(mut self) {
        drop(self.handle.intake);
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
    }
}

/// Delivery context threaded through the router: destination inboxes
/// plus the failover control plane (coordinator inbox, peer-death event
/// sink, and the per-node already-reported set backing its once-per-node
/// guarantee).
struct Sinks {
    workers: Vec<Sender<ToWorker>>,
    shards: Vec<Sender<ToShard>>,
    coordinator: Option<Sender<ToWorker>>,
    events: Option<Sender<PeerEvent>>,
    downed: crate::util::hash::FxHashSet<NodeId>,
}

impl Sinks {
    /// A send into a hung-up inbox means the node's thread exited — for
    /// a shard, either orderly shutdown or a kill fault. Surface it once
    /// per node as an unclean disconnect, exactly what the TCP reader
    /// reports when a peer process dies mid-run.
    fn note_down(&mut self, node: NodeId) {
        if !self.downed.insert(node) {
            return;
        }
        if let Some(ev) = &self.events {
            let _ = ev.send(PeerEvent::Disconnected { node, clean: false });
        }
    }
}

fn deliver(wire: Wire, sinks: &mut Sinks, stats: &NetStats) {
    // A sampled frame closes its in-transport segment (enqueue stamp ->
    // now) and stamps its inbox arrival for the handler's queue-wait
    // segment.
    if let Some(ring) = stats.spans.get() {
        if let Some(span) = wire.packet.span() {
            let now = SpanRing::now_us();
            let start = ring.take_mark(span.trace_id, Mark::Enqueue).unwrap_or(now);
            ring.record(span, "net", "transport_flush", start, now.saturating_sub(start));
            match wire.dst {
                NodeId::Shard(_) => ring.mark(span.trace_id, Mark::ArriveShard, now),
                NodeId::Worker(_) => ring.mark(span.trace_id, Mark::ArriveWorker, now),
                NodeId::Coordinator => {}
            }
        }
    }
    // Send errors mean the destination already exited: shutdown, or a
    // killed node — surfaced through the peer-event stream; the packet
    // itself is dropped either way.
    match (wire.dst, wire.packet) {
        (NodeId::Worker(i), Packet::ToWorker(m)) => {
            if sinks.workers[i].send(m).is_err() {
                sinks.note_down(NodeId::Worker(i));
            }
        }
        (NodeId::Shard(i), Packet::ToShard(m)) => {
            if sinks.shards[i].send(m).is_err() {
                sinks.note_down(NodeId::Shard(i));
            }
        }
        // Heartbeat replies to the coordinator's failure detector; a run
        // without one just drops them.
        (NodeId::Coordinator, Packet::ToWorker(m)) => {
            if let Some(c) = &sinks.coordinator {
                let _ = c.send(m);
            }
        }
        (dst, p) => panic!("packet {p:?} addressed to incompatible node {dst:?}"),
    }
    stats.delivered.fetch_add(1, Ordering::Release);
}

struct Scheduled {
    at: Instant,
    seq: u64,
    wire: Wire,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

#[allow(clippy::too_many_arguments)]
fn route_loop(
    cfg: NetConfig,
    rx: Receiver<Wire>,
    workers: Vec<Sender<ToWorker>>,
    shards: Vec<Sender<ToShard>>,
    coordinator: Option<Sender<ToWorker>>,
    events: Option<Sender<PeerEvent>>,
    stats: Arc<NetStats>,
    faults: Option<Arc<FaultInjector>>,
) {
    let mut sinks = Sinks {
        workers,
        shards,
        coordinator,
        events,
        downed: crate::util::hash::FxHashSet::default(),
    };
    if cfg.is_instant() && faults.is_none() {
        // Fast path: synchronous forwarding. (Link faults need the
        // scheduling loop even on an instant net — injected delays must
        // land in the heap.)
        while let Ok(wire) = rx.recv() {
            deliver(wire, &mut sinks, &stats);
        }
        return;
    }

    let mut rng = Rng::with_stream(cfg.seed, 0x6e65747e); // "net~"
    let mut heap: BinaryHeap<Reverse<Scheduled>> = BinaryHeap::new();
    // Per-link: when the link is next free (bandwidth serialization + FIFO).
    // Fx-hashed: these maps are touched once per message on the router's
    // hot loop.
    let mut link_free: FxHashMap<(NodeId, NodeId), Instant> = FxHashMap::default();
    // Per-link: latest scheduled delivery, to keep delivery FIFO (TCP-like)
    // even though jitter varies per message. The PS protocol depends on
    // Update-before-ClockTick ordering within a (worker, shard) link.
    let mut link_last: FxHashMap<(NodeId, NodeId), Instant> = FxHashMap::default();
    let mut seq = 0u64;
    let mut closed = false;
    // Reusable vectored-intake scratch: drained messages land here and
    // are scheduled in one pass, so steady-state wakeups allocate nothing
    // (drain keeps the capacity).
    let mut intake: Vec<Wire> = Vec::new();

    loop {
        // Dispatch everything due.
        let now = Instant::now();
        while heap.peek().is_some_and(|Reverse(s)| s.at <= now) {
            let Reverse(s) = heap.pop().unwrap();
            deliver(s.wire, &mut sinks, &stats);
        }
        if closed && heap.is_empty() {
            return;
        }
        // Wait for the next deadline or new intake.
        let timeout = heap
            .peek()
            .map(|Reverse(s)| s.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(first) => {
                // Vectored intake: coalesce everything queued at this
                // wakeup (up to the boundary) and schedule the batch in
                // one pass. Intake order — and with it the per-link FIFO
                // clamps and the jitter rng draw sequence — is exactly
                // what one-message-at-a-time processing would see.
                intake.push(first);
                while intake.len() < INTAKE_BATCH {
                    match rx.try_recv() {
                        Ok(w) => intake.push(w),
                        Err(_) => break,
                    }
                }
                // One arrival timestamp for the whole coalesced batch —
                // the frames "hit the NIC" together, like one writev.
                let now = Instant::now();
                for wire in intake.drain(..) {
                    let verdict = faults
                        .as_deref()
                        .map(|inj| inj.on_packet(wire.src, wire.dst))
                        .unwrap_or_default();
                    if verdict.drop {
                        // A dropped packet still settles — flush must not
                        // wait forever for a delivery that will never
                        // come.
                        stats.delivered.fetch_add(1, Ordering::Release);
                        continue;
                    }
                    let bytes = wire.packet.wire_bytes() as f64;
                    let ser = if cfg.bandwidth.is_finite() {
                        Duration::from_secs_f64(bytes / cfg.bandwidth)
                    } else {
                        Duration::ZERO
                    };
                    let jit = cfg.jitter.mul_f64(rng.f64());
                    let link = (wire.src, wire.dst);
                    let free_at =
                        link_free.get(&link).copied().unwrap_or(now).max(now) + ser;
                    link_free.insert(link, free_at);
                    let mut at = free_at + cfg.latency + jit + verdict.delay;
                    if verdict.reorder {
                        // Escape the FIFO clamp: fresh jitter, no clamp,
                        // and link_last untouched so later traffic may
                        // overtake.
                        at += cfg.jitter.mul_f64(rng.f64());
                    } else {
                        // FIFO per link: never deliver before an earlier
                        // message.
                        if let Some(&last) = link_last.get(&link) {
                            at = at.max(last + Duration::from_nanos(1));
                        }
                        link_last.insert(link, at);
                    }
                    seq += 1;
                    heap.push(Reverse(Scheduled { at, seq, wire }));
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => closed = true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::msg::ToShard;

    fn tick(worker: usize, clock: i64) -> Packet {
        Packet::ToShard(ToShard::ClockTick { worker, clock })
    }

    #[test]
    fn instant_delivers_immediately() {
        let (stx, srx) = channel();
        let net = SimNet::new(NetConfig::instant(), vec![], vec![stx]);
        net.handle()
            .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, 1));
        let msg = srx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert!(matches!(msg, ToShard::ClockTick { clock: 1, .. }));
        assert_eq!(net.messages(), 1);
        net.shutdown();
    }

    #[test]
    fn delayed_delivery_respects_latency() {
        let (stx, srx) = channel();
        let cfg = NetConfig {
            latency: Duration::from_millis(20),
            jitter: Duration::ZERO,
            bandwidth: f64::INFINITY,
            seed: 1,
        };
        let net = SimNet::new(cfg, vec![], vec![stx]);
        let t0 = Instant::now();
        net.handle()
            .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, 1));
        srx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(18), "{:?}", t0.elapsed());
        net.shutdown();
    }

    #[test]
    fn fifo_per_link() {
        let (stx, srx) = channel();
        let cfg = NetConfig {
            latency: Duration::from_millis(5),
            jitter: Duration::from_millis(5), // jitter could reorder w/o FIFO
            bandwidth: f64::INFINITY,
            seed: 2,
        };
        let net = SimNet::new(cfg, vec![], vec![stx]);
        for c in 0..20 {
            net.handle()
                .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, c));
        }
        let mut got = Vec::new();
        for _ in 0..20 {
            match srx.recv_timeout(Duration::from_secs(2)).unwrap() {
                ToShard::ClockTick { clock, .. } => got.push(clock),
                other => panic!("unexpected {other:?}"),
            }
        }
        // Delivery must be FIFO per link even with jitter (the PS protocol
        // depends on Update-before-ClockTick ordering).
        assert_eq!(got, (0..20).collect::<Vec<_>>());
        net.shutdown();
    }

    #[test]
    fn fifo_per_link_with_interleaved_senders() {
        // Two source links into one shard, interleaved sends under jitter
        // + bandwidth: delivery must stay FIFO *within* each link even
        // though the links race each other.
        let (stx, srx) = channel();
        let cfg = NetConfig {
            latency: Duration::from_millis(2),
            jitter: Duration::from_millis(4),
            bandwidth: 5e6,
            seed: 11,
        };
        let net = SimNet::new(cfg, vec![], vec![stx]);
        for c in 0..15 {
            net.handle()
                .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, c));
            net.handle()
                .send(NodeId::Worker(1), NodeId::Shard(0), tick(1, c));
        }
        let mut per_worker: [Vec<i64>; 2] = [Vec::new(), Vec::new()];
        for _ in 0..30 {
            match srx.recv_timeout(Duration::from_secs(2)).unwrap() {
                ToShard::ClockTick { worker, clock } => per_worker[worker].push(clock),
                other => panic!("unexpected {other:?}"),
            }
        }
        for (w, got) in per_worker.iter().enumerate() {
            assert_eq!(got, &(0..15).collect::<Vec<_>>(), "link {w} reordered");
        }
        net.shutdown();
    }

    #[test]
    fn shard_to_shard_traffic_routes_and_stays_fifo() {
        // Migration handoffs ride shard->shard links: packets with a
        // shard src deliver into the destination shard's inbox with the
        // same per-link FIFO guarantee as worker links (RowHandoff
        // streams must arrive before their MigrateCommit end-marker).
        let (stx0, _srx0) = channel();
        let (stx1, srx1) = channel();
        let cfg = NetConfig {
            latency: Duration::from_millis(2),
            jitter: Duration::from_millis(3),
            bandwidth: 10e6,
            seed: 5,
        };
        let net = SimNet::new(cfg, vec![], vec![stx0, stx1]);
        for epoch in 0..10 {
            net.handle().send(
                NodeId::Shard(0),
                NodeId::Shard(1),
                Packet::ToShard(ToShard::MigrateCommit { epoch }),
            );
        }
        let mut got = Vec::new();
        for _ in 0..10 {
            match srx1.recv_timeout(Duration::from_secs(2)).unwrap() {
                ToShard::MigrateCommit { epoch } => got.push(epoch),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        net.shutdown();
    }

    #[test]
    fn bandwidth_serializes_large_messages() {
        let (stx, srx) = channel();
        let cfg = NetConfig {
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            bandwidth: 1e6, // 1 MB/s
            seed: 3,
        };
        let net = SimNet::new(cfg, vec![], vec![stx]);
        // ~100 KB update => ~100 ms serialization.
        let big = ToShard::Update {
            worker: 0,
            clock: 0,
            rows: vec![((0, 0), vec![0.0f32; 25_000].into())],
            span: None,
        };
        let t0 = Instant::now();
        net.handle()
            .send(NodeId::Worker(0), NodeId::Shard(0), Packet::ToShard(big));
        srx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(80), "{:?}", t0.elapsed());
        net.shutdown();
    }

    #[test]
    fn fault_drop_discards_but_settles() {
        // Every packet dropped: nothing arrives, yet flush terminates
        // because drops count as settled.
        let plan = crate::sim::fault::FaultPlan::parse("seed=1;drop=w*-s*:1.0").unwrap();
        let inj = Arc::new(FaultInjector::new(plan));
        let (stx, srx) = channel();
        let net = SimNet::with_faults(NetConfig::instant(), vec![], vec![stx], Some(inj));
        for c in 0..10 {
            net.handle()
                .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, c));
        }
        net.flush();
        assert_eq!(srx.try_iter().count(), 0);
        net.shutdown();
    }

    #[test]
    fn fault_delay_postpones_delivery() {
        let plan = crate::sim::fault::FaultPlan::parse("delay=w0-s0:30ms").unwrap();
        let inj = Arc::new(FaultInjector::new(plan));
        let (stx, srx) = channel();
        let net = SimNet::with_faults(NetConfig::instant(), vec![], vec![stx], Some(inj));
        let t0 = Instant::now();
        net.handle()
            .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, 1));
        srx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25), "{:?}", t0.elapsed());
        net.shutdown();
    }

    #[test]
    fn shutdown_drains_queue() {
        let (stx, srx) = channel();
        let cfg = NetConfig {
            latency: Duration::from_millis(30),
            jitter: Duration::ZERO,
            bandwidth: f64::INFINITY,
            seed: 4,
        };
        let net = SimNet::new(cfg, vec![], vec![stx]);
        for c in 0..5 {
            net.handle()
                .send(NodeId::Worker(0), NodeId::Shard(0), tick(0, c));
        }
        net.shutdown(); // must block until the 5 ticks are delivered
        let got = srx.try_iter().count();
        assert_eq!(got, 5);
    }
}
