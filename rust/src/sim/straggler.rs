//! Straggler injection: per-(worker, clock) compute-slowdown factors.
//!
//! The paper's staleness phenomena (Fig. 1) arise from workers progressing
//! at different speeds; on a real cluster this comes from multi-tenancy,
//! GC pauses and OS jitter. The harness multiplies each worker's per-clock
//! compute time by `factor(worker, clock)`; a factor of 1.0 = no slowdown.
//! Factors are derived deterministically from (seed, worker, clock) so runs
//! are reproducible and SSP-vs-ESSP comparisons see identical straggling.

use crate::util::rng::{splitmix64, Rng};

/// Straggler model for a run.
#[derive(Debug, Clone, PartialEq)]
pub enum StragglerModel {
    /// Everyone runs at full speed.
    None,
    /// A fixed set of workers is permanently slow by `factor`.
    FixedSlow { workers: Vec<usize>, factor: f64 },
    /// Every (worker, clock) draws a factor uniformly from [1, max_factor].
    RandomUniform { max_factor: f64 },
    /// Heavy-tailed: factor 1 with prob 1-p, else Pareto-ish spike up to
    /// `max_factor` (models rare long pauses).
    Spikes { p: f64, max_factor: f64 },
    /// Deterministic rotation: worker w is slowed by `factor` on clocks
    /// where `clock % period == w % period` (models periodic interference
    /// sweeping across the cluster).
    Rotating { period: u64, factor: f64 },
}

impl StragglerModel {
    /// Slowdown multiplier for `worker` at `clock` (>= 1.0).
    pub fn factor(&self, seed: u64, worker: usize, clock: u64) -> f64 {
        match self {
            StragglerModel::None => 1.0,
            StragglerModel::FixedSlow { workers, factor } => {
                if workers.contains(&worker) {
                    *factor
                } else {
                    1.0
                }
            }
            StragglerModel::RandomUniform { max_factor } => {
                let mut r = Self::rng(seed, worker, clock);
                1.0 + (max_factor - 1.0) * r.f64()
            }
            StragglerModel::Spikes { p, max_factor } => {
                let mut r = Self::rng(seed, worker, clock);
                if r.f64() < *p {
                    // Inverse-CDF of a truncated Pareto(alpha=1) on
                    // [1, max_factor]: heavy tail, bounded.
                    let u = r.f64().max(1e-12);
                    (1.0 / (1.0 - u * (1.0 - 1.0 / max_factor))).min(*max_factor)
                } else {
                    1.0
                }
            }
            StragglerModel::Rotating { period, factor } => {
                if *period > 0 && clock % period == (worker as u64) % period {
                    *factor
                } else {
                    1.0
                }
            }
        }
    }

    fn rng(seed: u64, worker: usize, clock: u64) -> Rng {
        let mut s = seed ^ (worker as u64).wrapping_mul(0xA076_1D64_78BD_642F);
        let a = splitmix64(&mut s);
        Rng::with_stream(a ^ clock.wrapping_mul(0xE703_7ED1_A0B4_28DB), worker as u64)
    }

    /// Parse "none" | "fixed:0,2x4" | "uniform:3" | "spikes:0.05,10" |
    /// "rotating:8x5".
    pub fn parse(s: &str) -> Result<Self, String> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "none" => Ok(StragglerModel::None),
            "fixed" => {
                let a = arg.ok_or("fixed needs workers and factor, e.g. fixed:0,2x4")?;
                let (list, f) = a.split_once('x').ok_or("fixed:W,W,..xF")?;
                let workers = list
                    .split(',')
                    .map(|w| w.parse().map_err(|e| format!("bad worker: {e}")))
                    .collect::<Result<Vec<usize>, _>>()?;
                Ok(StragglerModel::FixedSlow {
                    workers,
                    factor: f.parse().map_err(|e| format!("bad factor: {e}"))?,
                })
            }
            "uniform" => Ok(StragglerModel::RandomUniform {
                max_factor: arg
                    .ok_or("uniform needs a max factor")?
                    .parse()
                    .map_err(|e| format!("bad factor: {e}"))?,
            }),
            "spikes" => {
                let a = arg.ok_or("spikes needs p,maxfactor")?;
                let (p, f) = a.split_once(',').ok_or("spikes:P,F")?;
                Ok(StragglerModel::Spikes {
                    p: p.parse().map_err(|e| format!("bad p: {e}"))?,
                    max_factor: f.parse().map_err(|e| format!("bad factor: {e}"))?,
                })
            }
            "rotating" => {
                let a = arg.ok_or("rotating needs periodxfactor")?;
                let (p, f) = a.split_once('x').ok_or("rotating:PxF")?;
                Ok(StragglerModel::Rotating {
                    period: p.parse().map_err(|e| format!("bad period: {e}"))?,
                    factor: f.parse().map_err(|e| format!("bad factor: {e}"))?,
                })
            }
            _ => Err(format!("unknown straggler model {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_one() {
        assert_eq!(StragglerModel::None.factor(0, 3, 17), 1.0);
    }

    #[test]
    fn fixed_slows_only_listed() {
        let m = StragglerModel::FixedSlow {
            workers: vec![1],
            factor: 4.0,
        };
        assert_eq!(m.factor(0, 1, 0), 4.0);
        assert_eq!(m.factor(0, 0, 0), 1.0);
    }

    #[test]
    fn uniform_in_range_and_deterministic() {
        let m = StragglerModel::RandomUniform { max_factor: 3.0 };
        for w in 0..4 {
            for c in 0..50 {
                let f = m.factor(7, w, c);
                assert!((1.0..=3.0).contains(&f));
                assert_eq!(f, m.factor(7, w, c), "must be reproducible");
            }
        }
    }

    #[test]
    fn spikes_mostly_one() {
        let m = StragglerModel::Spikes {
            p: 0.1,
            max_factor: 10.0,
        };
        let mut ones = 0;
        let n = 2000;
        for c in 0..n {
            let f = m.factor(3, 0, c);
            assert!((1.0..=10.0).contains(&f));
            if f == 1.0 {
                ones += 1;
            }
        }
        let frac = ones as f64 / n as f64;
        assert!((0.85..=0.95).contains(&frac), "spike rate off: {frac}");
    }

    #[test]
    fn rotating_pattern() {
        let m = StragglerModel::Rotating {
            period: 4,
            factor: 5.0,
        };
        assert_eq!(m.factor(0, 1, 5), 5.0); // 5 % 4 == 1
        assert_eq!(m.factor(0, 1, 6), 1.0);
    }

    #[test]
    fn parse_all() {
        assert_eq!(StragglerModel::parse("none").unwrap(), StragglerModel::None);
        assert_eq!(
            StragglerModel::parse("fixed:0,2x4").unwrap(),
            StragglerModel::FixedSlow {
                workers: vec![0, 2],
                factor: 4.0
            }
        );
        assert_eq!(
            StragglerModel::parse("uniform:3").unwrap(),
            StragglerModel::RandomUniform { max_factor: 3.0 }
        );
        assert!(StragglerModel::parse("bogus").is_err());
    }
}
