//! Deterministic, replayable fault injection (the adversity plane).
//!
//! A [`FaultPlan`] is a seeded schedule of failures parsed from one flag
//! string (`--fault-plan`), so every failure an experiment observes is
//! reproducible from the command line alone:
//!
//! ```text
//!   seed=7;crash=s0@5;pause=s1@3:10ms;delay=w*-s0:200us;drop=w*-s*:0.01
//! ```
//!
//! Clause grammar (`;`-separated, order-insensitive):
//!
//! | clause                  | meaning                                        |
//! |-------------------------|------------------------------------------------|
//! | `seed=N`                | seed for all probabilistic link decisions      |
//! | `kill=sI@C`             | shard `I` dies permanently at table clock `C`  |
//! | `crash=sI@C`            | shard `I` loses volatile state at clock `C` and recovers from its WAL |
//! | `pause=sI@C:DUR`        | shard `I` stalls for `DUR` at clock `C`        |
//! | `delay=SRC-DST:DUR`     | add `DUR` latency on matching links            |
//! | `drop=SRC-DST:P`        | drop each matching packet with probability `P` |
//! | `reorder=SRC-DST:P`     | re-queue each matching packet with fresh       |
//! |                         | jitter, escaping the link FIFO clamp (sim only)|
//! | `fsync-stall=DUR`       | every WAL/checkpoint fsync stalls for `DUR`    |
//!
//! Node selectors: `w3` / `s0` (one node), `w*` / `s*` (any worker/shard),
//! `*` (any node). Durations take a `us`/`ms`/`s` suffix.
//!
//! Probabilistic decisions are pure functions of `(seed, src, dst, seq)`
//! where `seq` counts packets per link — the same plan over the same
//! traffic drops the same packets, every run. `kill`/`crash`/`pause` fire
//! at a *table-clock commit boundary*, the one point every deterministic
//! run passes through in the same state regardless of thread scheduling;
//! this is what makes a crash-recover run comparable bit-for-bit against
//! an undisturbed one.
//!
//! Caveats by transport: `delay`, `drop` and `fsync-stall` apply to both
//! SimNet and TCP; `reorder` is sim-only (a TCP stream cannot reorder).
//! `drop`/`reorder` deliberately violate the FIFO-reliable contract the
//! PS protocol assumes — they exist to probe behaviour beyond the
//! supported envelope, not for the equivalence tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::ps::types::Clock;
use crate::telemetry::registry::{MetricsSource, Snapshot};
use crate::transport::NodeId;
use crate::util::hash::FxHashMap;
use crate::util::rng::splitmix64;

/// One side of a link pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeSel {
    Any,
    AnyWorker,
    AnyShard,
    Worker(usize),
    Shard(usize),
}

impl NodeSel {
    pub fn matches(&self, node: NodeId) -> bool {
        match (self, node) {
            (NodeSel::Any, _) => true,
            (NodeSel::AnyWorker, NodeId::Worker(_)) => true,
            (NodeSel::AnyShard, NodeId::Shard(_)) => true,
            (NodeSel::Worker(w), NodeId::Worker(n)) => *w == n,
            (NodeSel::Shard(s), NodeId::Shard(n)) => *s == n,
            _ => false,
        }
    }

    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "*" => Ok(NodeSel::Any),
            "w*" => Ok(NodeSel::AnyWorker),
            "s*" => Ok(NodeSel::AnyShard),
            _ => {
                let (kind, idx) = s.split_at(1);
                let n: usize = idx
                    .parse()
                    .map_err(|_| format!("bad node selector {s:?} (want w3, s0, w*, s*, *)"))?;
                match kind {
                    "w" => Ok(NodeSel::Worker(n)),
                    "s" => Ok(NodeSel::Shard(n)),
                    _ => Err(format!("bad node selector {s:?} (want w3, s0, w*, s*, *)")),
                }
            }
        }
    }
}

/// A per-link network fault: fixed extra delay and/or probabilistic
/// drop/reorder on packets whose (src, dst) match the selectors.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkFault {
    pub src: NodeSel,
    pub dst: NodeSel,
    pub delay: Option<Duration>,
    pub drop: f64,
    pub reorder: f64,
}

/// What a shard does when its fault clock arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAction {
    /// Die permanently: the shard stops processing and never dumps; its
    /// replica is promoted to cover the partition.
    Kill,
    /// Amnesia: drop all volatile state, then recover from checkpoint +
    /// WAL tail and keep serving.
    Crash,
    /// Stall the shard thread for the duration (a transient gray failure).
    Pause(Duration),
}

/// One scheduled shard fault, fired at the first table-clock commit with
/// `new_min >= at_clock`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardFault {
    pub shard: usize,
    pub at_clock: Clock,
    pub action: ShardAction,
}

/// The full seeded fault schedule for one run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub links: Vec<LinkFault>,
    pub shards: Vec<ShardFault>,
    pub fsync_stall: Option<Duration>,
}

fn parse_duration(s: &str) -> Result<Duration, String> {
    let (num, mul_us) = if let Some(n) = s.strip_suffix("us") {
        (n, 1u64)
    } else if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000)
    } else {
        return Err(format!("bad duration {s:?} (want e.g. 200us, 10ms, 2s)"));
    };
    let v: u64 = num
        .parse()
        .map_err(|_| format!("bad duration {s:?} (want e.g. 200us, 10ms, 2s)"))?;
    Ok(Duration::from_micros(v * mul_us))
}

fn parse_link(rest: &str) -> Result<(NodeSel, NodeSel, &str), String> {
    // SRC-DST:VALUE
    let (pair, value) = rest
        .split_once(':')
        .ok_or_else(|| format!("bad link clause {rest:?} (want SRC-DST:VALUE)"))?;
    let (src, dst) = pair
        .split_once('-')
        .ok_or_else(|| format!("bad link pattern {pair:?} (want SRC-DST)"))?;
    Ok((NodeSel::parse(src)?, NodeSel::parse(dst)?, value))
}

fn parse_shard_at(rest: &str) -> Result<(usize, Clock, Option<&str>), String> {
    // sI@C[:EXTRA]
    let (sel, at) = rest
        .split_once('@')
        .ok_or_else(|| format!("bad shard clause {rest:?} (want sI@CLOCK)"))?;
    let shard = match NodeSel::parse(sel)? {
        NodeSel::Shard(s) => s,
        _ => return Err(format!("shard faults need a concrete shard, got {sel:?}")),
    };
    let (clock, extra) = match at.split_once(':') {
        Some((c, e)) => (c, Some(e)),
        None => (at, None),
    };
    let at_clock: Clock = clock
        .parse()
        .map_err(|_| format!("bad fault clock {clock:?}"))?;
    if at_clock < 0 {
        return Err(format!("fault clock must be >= 0, got {at_clock}"));
    }
    Ok((shard, at_clock, extra))
}

fn parse_prob(s: &str) -> Result<f64, String> {
    let p: f64 = s.parse().map_err(|_| format!("bad probability {s:?}"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("probability {p} outside [0, 1]"));
    }
    Ok(p)
}

impl FaultPlan {
    /// Parse the `--fault-plan` clause string. Empty string = empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';').filter(|c| !c.trim().is_empty()) {
            let (key, rest) = clause
                .trim()
                .split_once('=')
                .ok_or_else(|| format!("bad fault clause {clause:?} (want key=value)"))?;
            match key {
                "seed" => {
                    plan.seed = rest.parse().map_err(|_| format!("bad seed {rest:?}"))?;
                }
                "kill" | "crash" => {
                    let (shard, at_clock, extra) = parse_shard_at(rest)?;
                    if extra.is_some() {
                        return Err(format!("{key}={rest}: unexpected trailing value"));
                    }
                    let action = if key == "kill" {
                        ShardAction::Kill
                    } else {
                        ShardAction::Crash
                    };
                    plan.shards.push(ShardFault { shard, at_clock, action });
                }
                "pause" => {
                    let (shard, at_clock, extra) = parse_shard_at(rest)?;
                    let dur = parse_duration(
                        extra.ok_or_else(|| format!("pause={rest}: missing :DURATION"))?,
                    )?;
                    plan.shards.push(ShardFault {
                        shard,
                        at_clock,
                        action: ShardAction::Pause(dur),
                    });
                }
                "delay" => {
                    let (src, dst, v) = parse_link(rest)?;
                    plan.links.push(LinkFault {
                        src,
                        dst,
                        delay: Some(parse_duration(v)?),
                        drop: 0.0,
                        reorder: 0.0,
                    });
                }
                "drop" => {
                    let (src, dst, v) = parse_link(rest)?;
                    plan.links.push(LinkFault {
                        src,
                        dst,
                        delay: None,
                        drop: parse_prob(v)?,
                        reorder: 0.0,
                    });
                }
                "reorder" => {
                    let (src, dst, v) = parse_link(rest)?;
                    plan.links.push(LinkFault {
                        src,
                        dst,
                        delay: None,
                        drop: 0.0,
                        reorder: parse_prob(v)?,
                    });
                }
                "fsync-stall" => plan.fsync_stall = Some(parse_duration(rest)?),
                other => return Err(format!("unknown fault clause {other:?}")),
            }
        }
        Ok(plan)
    }

    /// The scheduled faults for one shard, in clock order.
    pub fn shard_faults(&self, shard: usize) -> Vec<ShardFault> {
        let mut v: Vec<ShardFault> = self
            .shards
            .iter()
            .filter(|f| f.shard == shard)
            .copied()
            .collect();
        v.sort_by_key(|f| f.at_clock);
        v
    }

    /// Shards scheduled to die permanently (their dumps never arrive).
    pub fn killed_shards(&self) -> Vec<usize> {
        self.shards
            .iter()
            .filter(|f| f.action == ShardAction::Kill)
            .map(|f| f.shard)
            .collect()
    }

    /// True if any link fault could touch traffic.
    pub fn has_link_faults(&self) -> bool {
        !self.links.is_empty()
    }
}

/// Verdict for one packet on one link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LinkVerdict {
    pub delay: Duration,
    pub drop: bool,
    pub reorder: bool,
}

/// Stateful evaluator of a [`FaultPlan`]'s link faults: a per-link packet
/// counter makes each probabilistic decision a pure function of
/// `(seed, src, dst, seq)` — deterministic and replayable, independent of
/// wall-clock or thread scheduling (given the transport presents packets
/// per link in a deterministic order, which FIFO links do).
pub struct FaultInjector {
    plan: FaultPlan,
    seqs: Mutex<FxHashMap<(NodeId, NodeId), u64>>,
    /// Verdict tallies: how often the plan actually touched traffic.
    /// Deterministic given deterministic traffic (they count verdicts,
    /// not wall-clock effects), so two replayed runs agree on them.
    evaluated: AtomicU64,
    drop_verdicts: AtomicU64,
    delay_verdicts: AtomicU64,
    reorder_verdicts: AtomicU64,
}

fn node_word(n: NodeId) -> u64 {
    match n {
        NodeId::Worker(w) => 0x1000_0000_0000 | w as u64,
        NodeId::Shard(s) => 0x2000_0000_0000 | s as u64,
        NodeId::Coordinator => 0x3000_0000_0000,
    }
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            seqs: Mutex::new(FxHashMap::default()),
            evaluated: AtomicU64::new(0),
            drop_verdicts: AtomicU64::new(0),
            delay_verdicts: AtomicU64::new(0),
            reorder_verdicts: AtomicU64::new(0),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Evaluate the plan against one packet. Advances the link's sequence
    /// counter only when some fault matches the link, so fault-free links
    /// stay contention-free in spirit (one map lookup, no decisions).
    pub fn on_packet(&self, src: NodeId, dst: NodeId) -> LinkVerdict {
        let mut verdict = LinkVerdict::default();
        let matching: Vec<&LinkFault> = self
            .plan
            .links
            .iter()
            .filter(|f| f.src.matches(src) && f.dst.matches(dst))
            .collect();
        if matching.is_empty() {
            return verdict;
        }
        let seq = {
            let mut seqs = self.seqs.lock().unwrap();
            let c = seqs.entry((src, dst)).or_insert(0);
            let s = *c;
            *c += 1;
            s
        };
        for (i, f) in matching.iter().enumerate() {
            if let Some(d) = f.delay {
                verdict.delay += d;
            }
            // Independent streams per (link, seq, fault index, kind).
            if f.drop > 0.0 && self.decide(src, dst, seq, (i as u64) << 1, f.drop) {
                verdict.drop = true;
            }
            if f.reorder > 0.0 && self.decide(src, dst, seq, ((i as u64) << 1) | 1, f.reorder) {
                verdict.reorder = true;
            }
        }
        self.evaluated.fetch_add(1, Ordering::Relaxed);
        if verdict.drop {
            self.drop_verdicts.fetch_add(1, Ordering::Relaxed);
        }
        if !verdict.delay.is_zero() {
            self.delay_verdicts.fetch_add(1, Ordering::Relaxed);
        }
        if verdict.reorder {
            self.reorder_verdicts.fetch_add(1, Ordering::Relaxed);
        }
        verdict
    }

    /// Packets a link fault was evaluated against (fault-free links are
    /// never counted — they take the early return above).
    pub fn evaluated(&self) -> u64 {
        self.evaluated.load(Ordering::Relaxed)
    }

    /// Packets the plan decided to drop.
    pub fn drop_verdicts(&self) -> u64 {
        self.drop_verdicts.load(Ordering::Relaxed)
    }

    /// Packets the plan decided to delay.
    pub fn delay_verdicts(&self) -> u64 {
        self.delay_verdicts.load(Ordering::Relaxed)
    }

    /// Packets the plan decided to reorder (sim only).
    pub fn reorder_verdicts(&self) -> u64 {
        self.reorder_verdicts.load(Ordering::Relaxed)
    }

    /// The configured fsync stall, if any.
    pub fn fsync_stall(&self) -> Option<Duration> {
        self.plan.fsync_stall
    }

    fn decide(&self, src: NodeId, dst: NodeId, seq: u64, stream: u64, p: f64) -> bool {
        let mut s = self.plan.seed
            ^ node_word(src).rotate_left(17)
            ^ node_word(dst).rotate_left(41)
            ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ stream.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        let x = splitmix64(&mut s);
        // Map to [0, 1) with 53-bit precision, same construction as Rng::f64.
        let u = (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < p
    }
}

impl MetricsSource for FaultInjector {
    /// Scrape view of the verdict tallies (node `faults`), so a faulted
    /// run's admin endpoint shows how much adversity actually fired.
    fn snapshots(&self) -> Vec<Snapshot> {
        vec![Snapshot {
            node: "faults".into(),
            entries: vec![
                ("evaluated".into(), self.evaluated()),
                ("drop_verdicts".into(), self.drop_verdicts()),
                ("delay_verdicts".into(), self.delay_verdicts()),
                ("reorder_verdicts".into(), self.reorder_verdicts()),
            ],
        }]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_grammar() {
        let p = FaultPlan::parse(
            "seed=7;kill=s0@5;crash=s1@3;pause=s2@4:10ms;\
             delay=w*-s0:200us;drop=w1-s*:0.25;reorder=*-*:0.5;fsync-stall=2ms",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.shards.len(), 3);
        assert_eq!(
            p.shards[0],
            ShardFault { shard: 0, at_clock: 5, action: ShardAction::Kill }
        );
        assert_eq!(p.shards[1].action, ShardAction::Crash);
        assert_eq!(
            p.shards[2].action,
            ShardAction::Pause(Duration::from_millis(10))
        );
        assert_eq!(p.links.len(), 3);
        assert_eq!(p.links[0].delay, Some(Duration::from_micros(200)));
        assert_eq!(p.links[1].drop, 0.25);
        assert_eq!(p.links[2].reorder, 0.5);
        assert_eq!(p.fsync_stall, Some(Duration::from_millis(2)));
        assert_eq!(p.killed_shards(), vec![0]);
        assert_eq!(p.shard_faults(1).len(), 1);
    }

    #[test]
    fn empty_plan_is_empty() {
        let p = FaultPlan::parse("").unwrap();
        assert_eq!(p, FaultPlan::default());
        assert!(!p.has_link_faults());
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "boom",
            "kill=w0@5",     // faults target shards, not workers
            "kill=s0",       // missing @clock
            "kill=s0@-1",    // negative clock
            "pause=s0@3",    // missing duration
            "drop=w0-s0:1.5",// probability out of range
            "delay=w0:10ms", // missing -DST
            "delay=w0-s0:10",// missing duration suffix
            "seed=x",
            "frob=1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn decisions_are_deterministic_and_per_link() {
        let plan = FaultPlan::parse("seed=9;drop=w*-s*:0.5").unwrap();
        let a = FaultInjector::new(plan.clone());
        let b = FaultInjector::new(plan);
        let w0 = NodeId::Worker(0);
        let s0 = NodeId::Shard(0);
        let s1 = NodeId::Shard(1);
        let seq_a: Vec<bool> = (0..64).map(|_| a.on_packet(w0, s0).drop).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.on_packet(w0, s0).drop).collect();
        assert_eq!(seq_a, seq_b, "same plan, same traffic, same drops");
        assert!(seq_a.iter().any(|&d| d) && seq_a.iter().any(|&d| !d));
        // An unmatched link is untouched and consumes no randomness.
        let v = a.on_packet(s0, s1);
        assert_eq!(v, LinkVerdict::default());
    }

    #[test]
    fn delay_applies_without_randomness() {
        let plan = FaultPlan::parse("delay=w1-s0:300us").unwrap();
        let inj = FaultInjector::new(plan);
        let v = inj.on_packet(NodeId::Worker(1), NodeId::Shard(0));
        assert_eq!(v.delay, Duration::from_micros(300));
        assert!(!v.drop && !v.reorder);
        let v = inj.on_packet(NodeId::Worker(0), NodeId::Shard(0));
        assert_eq!(v.delay, Duration::ZERO);
    }

    #[test]
    fn verdict_tallies_count_what_fired() {
        let plan = FaultPlan::parse("seed=9;drop=w*-s*:0.5;delay=w*-s0:100us").unwrap();
        let inj = FaultInjector::new(plan);
        let w0 = NodeId::Worker(0);
        let s0 = NodeId::Shard(0);
        let drops = (0..64).filter(|_| inj.on_packet(w0, s0).drop).count() as u64;
        assert_eq!(inj.evaluated(), 64);
        assert_eq!(inj.drop_verdicts(), drops);
        assert!(drops > 0);
        // Every matching packet carried the fixed delay.
        assert_eq!(inj.delay_verdicts(), 64);
        assert_eq!(inj.reorder_verdicts(), 0);
        // Fault-free links take the early return: nothing is tallied.
        inj.on_packet(s0, NodeId::Shard(1));
        assert_eq!(inj.evaluated(), 64);
        // The scrape view mirrors the accessors.
        let snaps = inj.snapshots();
        assert_eq!(snaps[0].node, "faults");
        assert_eq!(snaps[0].get("drop_verdicts"), Some(drops));
    }

    #[test]
    fn selector_matching() {
        use NodeSel::*;
        assert!(Any.matches(NodeId::Coordinator));
        assert!(AnyWorker.matches(NodeId::Worker(3)));
        assert!(!AnyWorker.matches(NodeId::Shard(3)));
        assert!(Shard(2).matches(NodeId::Shard(2)));
        assert!(!Shard(2).matches(NodeId::Shard(1)));
    }
}
