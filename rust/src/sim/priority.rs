//! Thread-priority separation for the single-core testbed.
//!
//! On the paper's clusters, parameter-server processes and the network
//! stack run on their own cores/NICs; worker computation cannot starve
//! message delivery. On this 1-core testbed, a compute-bound worker
//! thread can delay the simnet router and shard threads by whole
//! scheduler quanta, which would inject *scheduling* latency that has no
//! analogue in the modeled system (it made ESSP pushes look ~3 clocks
//! late). We emulate dedicated communication hardware by raising the
//! priority of infrastructure threads and lowering worker threads
//! (DESIGN.md §Substitutions).
//!
//! Uses plain `nice` values; raising priority needs root (true in this
//! environment) and degrades gracefully to a no-op otherwise.
//!
//! The offline vendor set has no `libc` crate, so the two symbols we need
//! (`syscall` for gettid, `setpriority`) are declared directly against
//! the C library every Linux target already links.

/// Mark the calling thread as infrastructure (router, shard, runtime).
pub fn infrastructure_thread() {
    set_nice(-10);
}

/// Mark the calling thread as a compute worker.
pub fn worker_thread() {
    set_nice(5);
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn set_nice(value: i32) {
    use std::ffi::{c_int, c_long};
    #[cfg(target_arch = "x86_64")]
    const SYS_GETTID: c_long = 186;
    #[cfg(target_arch = "aarch64")]
    const SYS_GETTID: c_long = 178;
    const PRIO_PROCESS: c_int = 0;
    extern "C" {
        fn syscall(num: c_long, ...) -> c_long;
        fn setpriority(which: c_int, who: u32, prio: c_int) -> c_int;
    }
    // Per-thread nice: setpriority(PRIO_PROCESS, tid, value) on Linux.
    unsafe {
        let tid = syscall(SYS_GETTID) as u32;
        // Ignore failures (non-root lowering of nice): priorities are an
        // optimization of the simulation's fidelity, not a correctness
        // requirement.
        setpriority(PRIO_PROCESS, tid, value);
    }
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn set_nice(_value: i32) {
    // Unsupported platform: scheduling priority is best-effort only.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_calls_do_not_crash() {
        let h = std::thread::spawn(|| {
            infrastructure_thread();
            worker_thread();
        });
        h.join().unwrap();
    }
}
