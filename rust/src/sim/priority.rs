//! Thread-priority separation for the single-core testbed.
//!
//! On the paper's clusters, parameter-server processes and the network
//! stack run on their own cores/NICs; worker computation cannot starve
//! message delivery. On this 1-core testbed, a compute-bound worker
//! thread can delay the simnet router and shard threads by whole
//! scheduler quanta, which would inject *scheduling* latency that has no
//! analogue in the modeled system (it made ESSP pushes look ~3 clocks
//! late). We emulate dedicated communication hardware by raising the
//! priority of infrastructure threads and lowering worker threads
//! (DESIGN.md §Substitutions).
//!
//! Uses plain `nice` values; raising priority needs root (true in this
//! environment) and degrades gracefully to a no-op otherwise.

/// Mark the calling thread as infrastructure (router, shard, runtime).
pub fn infrastructure_thread() {
    set_nice(-10);
}

/// Mark the calling thread as a compute worker.
pub fn worker_thread() {
    set_nice(5);
}

fn set_nice(value: i32) {
    // Per-thread nice: setpriority(PRIO_PROCESS, tid, value) on Linux.
    unsafe {
        let tid = libc::syscall(libc::SYS_gettid) as libc::id_t;
        // Ignore failures (non-root lowering of nice, unsupported OS):
        // priorities are an optimization of the simulation's fidelity,
        // not a correctness requirement.
        libc::setpriority(libc::PRIO_PROCESS, tid, value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_calls_do_not_crash() {
        let h = std::thread::spawn(|| {
            infrastructure_thread();
            worker_thread();
        });
        h.join().unwrap();
    }
}
