//! Synthetic LDA corpus from the model's own generative story
//! (DESIGN.md §5 substitution for NYT): φ_k ~ Dir(β₀) over the vocabulary,
//! θ_d ~ Dir(α₀) over topics, tokens ~ Mult(θ_d) ∘ Mult(φ_z). Gibbs on
//! such a corpus exhibits the same PS access pattern (hot word rows,
//! doc-major traversal) and a log-likelihood ascent like the paper's.

use super::LdaConfig;
use crate::util::rng::Rng;

/// A corpus: docs of token ids.
#[derive(Debug)]
pub struct Corpus {
    pub docs: Vec<Vec<u32>>,
    pub cfg: LdaConfig,
}

impl Corpus {
    pub fn generate(cfg: &LdaConfig) -> Self {
        cfg.validate().expect("invalid LdaConfig");
        let mut rng = Rng::with_stream(cfg.seed, 0x1DA);
        // Topic-word distributions.
        let phi: Vec<Vec<f64>> = (0..cfg.topics)
            .map(|_| rng.dirichlet(cfg.gen_beta, cfg.vocab))
            .collect();
        let docs = (0..cfg.docs)
            .map(|_| {
                let theta = rng.dirichlet(cfg.gen_alpha, cfg.topics);
                (0..cfg.doc_len)
                    .map(|_| {
                        let z = rng.categorical(&theta);
                        rng.categorical(&phi[z]) as u32
                    })
                    .collect()
            })
            .collect();
        Self {
            docs,
            cfg: cfg.clone(),
        }
    }

    pub fn total_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }

    /// Docs owned by `worker` (striped).
    pub fn docs_for_worker(&self, worker: usize, workers: usize) -> Vec<usize> {
        (0..self.docs.len())
            .filter(|d| d % workers == worker)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = LdaConfig::default();
        let a = Corpus::generate(&cfg);
        let b = Corpus::generate(&cfg);
        assert_eq!(a.docs[..10], b.docs[..10]);
    }

    #[test]
    fn shape_and_bounds() {
        let cfg = LdaConfig {
            docs: 50,
            doc_len: 20,
            vocab: 100,
            ..Default::default()
        };
        let c = Corpus::generate(&cfg);
        assert_eq!(c.docs.len(), 50);
        assert_eq!(c.total_tokens(), 1000);
        assert!(c
            .docs
            .iter()
            .flatten()
            .all(|&w| (w as usize) < cfg.vocab));
    }

    #[test]
    fn topical_structure_exists() {
        // A topic-concentrated corpus has lower unigram entropy per doc
        // than the global unigram distribution: docs reuse few topics'
        // vocabularies. Check docs have repeated words (non-uniformity).
        let cfg = LdaConfig {
            docs: 40,
            doc_len: 100,
            vocab: 2000,
            gen_alpha: 0.05,
            gen_beta: 0.01,
            ..Default::default()
        };
        let c = Corpus::generate(&cfg);
        let mut repeats = 0usize;
        for d in &c.docs {
            let mut sorted = d.clone();
            sorted.sort_unstable();
            let n = sorted.len();
            sorted.dedup();
            repeats += n - sorted.len();
        }
        // With uniform sampling over 2000 words, ~2.5 repeats/doc expected;
        // topic concentration should give far more.
        assert!(
            repeats > 40 * 10,
            "corpus lacks topical concentration: {repeats} repeats"
        );
    }

    #[test]
    fn worker_striping_partitions() {
        let c = Corpus::generate(&LdaConfig::default());
        let total: usize = (0..3).map(|w| c.docs_for_worker(w, 3).len()).sum();
        assert_eq!(total, c.docs.len());
    }
}
