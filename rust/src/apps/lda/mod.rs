//! LDA topic modeling via collapsed Gibbs sampling on the parameter
//! server — the paper's second benchmark (NYT corpus, V=100k, K=100,
//! 8 nodes; here a synthetic Dirichlet-generated corpus scaled to the
//! testbed, DESIGN.md §5).
//!
//! PS layout, as in the paper: the word-topic count table (one PS row per
//! vocabulary word, K floats) and the topic-total row are globally shared;
//! doc-topic counts and topic assignments stay worker-local. Counts are
//! float-valued on the server because updates are additive INCs (the paper
//! does the same — commutative/associative coalescing needs a group, and
//! negative in-flight counts are tolerated by the sampler via clamping).

pub mod corpus;
pub mod gibbs;

use crate::ps::types::TableId;

/// PS table: word-topic counts, V rows x K.
pub const WT_TABLE: TableId = 10;
/// PS table: topic totals, 1 row x K.
pub const TOPIC_TABLE: TableId = 11;

/// LDA workload configuration.
#[derive(Debug, Clone)]
pub struct LdaConfig {
    pub vocab: usize,
    pub topics: usize,
    pub docs: usize,
    /// Tokens per document.
    pub doc_len: usize,
    /// Dirichlet hyperparameters of the *generative* model.
    pub gen_alpha: f64,
    pub gen_beta: f64,
    /// Sampler hyperparameters.
    pub alpha: f64,
    pub beta: f64,
    /// Fraction of a worker's docs swept per clock (paper: 50% minibatch).
    pub minibatch: f64,
    pub seed: u64,
}

impl Default for LdaConfig {
    fn default() -> Self {
        Self {
            vocab: 500,
            topics: 10,
            docs: 400,
            doc_len: 64,
            gen_alpha: 0.08,
            gen_beta: 0.05,
            alpha: 0.1,
            beta: 0.1,
            minibatch: 0.5,
            seed: 11,
        }
    }
}

impl LdaConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.topics > 0 && self.vocab > 1 && self.docs > 0);
        anyhow::ensure!(self.minibatch > 0.0 && self.minibatch <= 1.0);
        Ok(())
    }
}
