//! Collapsed Gibbs sampler for LDA over the PS (the paper's LDA worker).
//!
//! Per token w in doc d with current assignment z:
//!   1. decrement n_dk locally; INC(-1) on the word row and topic row;
//!   2. sample z' ∝ (n_wk + β)(n_dk + α) / (n_k + Vβ) from the PS view;
//!   3. increment n_d,z' locally; INC(+1) on word/topic rows.
//!
//! The word-topic and topic-total counts read in step 2 are *stale* under
//! SSP/ESSP — that staleness is exactly what the paper studies. Counts are
//! clamped at >= 0 in the sampler: in-flight negative INCs can transiently
//! undershoot, which the error-tolerance argument of the paper covers.
//!
//! Each token's ±1 INCs touch 1–2 indices of a K-topic row and enter the
//! PS as sparse pairs (`PsClient::inc_sparse`). They stay sparse
//! end-to-end — coalesced as pairs, shipped as `len | nnz | (idx,val)*`,
//! applied without densification — so a word-topic flush costs O(nnz)
//! wire bytes instead of O(K) (see `ps::update`). Only the hot
//! topic-total row (every token increments it) crosses the density
//! threshold and densifies, which is exactly when dense is cheaper.

use std::sync::Arc;

use crate::ps::client::PsClient;
use crate::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use crate::ps::types::{Clock, RowId};
use crate::util::rng::Rng;

use super::corpus::Corpus;
use super::{LdaConfig, TOPIC_TABLE, WT_TABLE};

/// Per-worker LDA Gibbs sampler.
pub struct LdaWorker {
    corpus: Arc<Corpus>,
    cfg: LdaConfig,
    my_docs: Vec<usize>,
    /// Assignments per owned doc (parallel to corpus docs' tokens).
    z: Vec<Vec<u8>>,
    /// Local doc-topic counts per owned doc.
    ndk: Vec<Vec<f32>>,
    rng: Rng,
    cursor: usize,
    initialized: bool,
    /// Reusable read/sampling buffers: the per-token hot loop reads the
    /// word-topic and topic-total rows via `get_into` and fills `weights`
    /// in place, so steady-state sweeps perform no per-token allocation.
    nwk_buf: Vec<f32>,
    nk_buf: Vec<f32>,
    weights_buf: Vec<f64>,
}

impl LdaWorker {
    pub fn new(corpus: Arc<Corpus>, worker: usize, workers: usize) -> Self {
        let cfg = corpus.cfg.clone();
        let my_docs = corpus.docs_for_worker(worker, workers);
        let rng = Rng::with_stream(cfg.seed ^ 0x91bb5, worker as u64);
        Self {
            corpus,
            cfg,
            my_docs,
            z: Vec::new(),
            ndk: Vec::new(),
            rng,
            cursor: 0,
            initialized: false,
            nwk_buf: Vec::new(),
            nk_buf: Vec::new(),
            weights_buf: Vec::new(),
        }
    }

    /// Random init: assign topics uniformly, push all counts to the PS.
    fn init(&mut self, ps: &mut PsClient) {
        let k = self.cfg.topics;
        for &doc in &self.my_docs {
            let tokens = &self.corpus.docs[doc];
            let mut zs = Vec::with_capacity(tokens.len());
            let mut counts = vec![0.0f32; k];
            for &w in tokens {
                let topic = self.rng.usize_below(k) as u8;
                zs.push(topic);
                counts[topic as usize] += 1.0;
                ps.inc_sparse((WT_TABLE, w as RowId), &[(topic as usize, 1.0)]);
                ps.inc_sparse((TOPIC_TABLE, 0), &[(topic as usize, 1.0)]);
            }
            self.z.push(zs);
            self.ndk.push(counts);
        }
        self.initialized = true;
    }

    fn docs_per_clock(&self) -> usize {
        ((self.my_docs.len() as f64 * self.cfg.minibatch).ceil() as usize)
            .max(1)
            .min(self.my_docs.len().max(1))
    }

    /// One Gibbs sweep over a doc. Returns the doc's log-likelihood
    /// contribution under the *current* (stale) PS view.
    fn sweep_doc(&mut self, ps: &mut PsClient, local_idx: usize) -> f64 {
        let k = self.cfg.topics;
        let (alpha, beta) = (self.cfg.alpha as f32, self.cfg.beta as f32);
        let vbeta = self.cfg.vocab as f32 * beta;
        let doc = self.my_docs[local_idx];
        // Clone to satisfy the borrow checker; doc_len * 1 byte is tiny.
        let tokens = self.corpus.docs[doc].clone();
        let mut loglik = 0.0f64;
        let doc_len = tokens.len() as f32;

        // Reuse the worker's buffers across tokens (no per-token allocs).
        let mut nwk = std::mem::take(&mut self.nwk_buf);
        let mut nk = std::mem::take(&mut self.nk_buf);
        let mut weights = std::mem::take(&mut self.weights_buf);
        weights.clear();
        weights.resize(k, 0.0);

        for (t, &w) in tokens.iter().enumerate() {
            let old = self.z[local_idx][t] as usize;
            // 1. Remove the token from the counts.
            self.ndk[local_idx][old] -= 1.0;
            ps.inc_sparse((WT_TABLE, w as RowId), &[(old, -1.0)]);
            ps.inc_sparse((TOPIC_TABLE, 0), &[(old, -1.0)]);

            // 2. Sample from the conditional under the (stale) PS view.
            ps.get_into((WT_TABLE, w as RowId), &mut nwk);
            ps.get_into((TOPIC_TABLE, 0), &mut nk);
            let ndk = &self.ndk[local_idx];
            let mut p_token = 0.0f64; // predictive p(w|d) for log-lik
            for kk in 0..k {
                let a = (nwk[kk].max(0.0) + beta) as f64;
                let b = (ndk[kk].max(0.0) + alpha) as f64;
                let c = (nk[kk].max(0.0) + vbeta) as f64;
                weights[kk] = a * b / c;
                p_token += (a / c) * (b / (doc_len - 1.0 + k as f32 * alpha) as f64);
            }
            let new = self.rng.categorical(&weights);
            loglik += p_token.max(1e-300).ln();

            // 3. Add it back under the new topic.
            self.z[local_idx][t] = new as u8;
            self.ndk[local_idx][new] += 1.0;
            ps.inc_sparse((WT_TABLE, w as RowId), &[(new, 1.0)]);
            ps.inc_sparse((TOPIC_TABLE, 0), &[(new, 1.0)]);
        }

        self.nwk_buf = nwk;
        self.nk_buf = nk;
        self.weights_buf = weights;
        loglik
    }
}

impl PsApp for LdaWorker {
    fn run_clock(&mut self, ps: &mut PsClient, _clock: Clock) -> Option<f64> {
        if !self.initialized {
            self.init(ps);
            return None; // counts not yet global: no metric for clock 0
        }
        if self.my_docs.is_empty() {
            return None;
        }
        let n = self.docs_per_clock();
        let mut loglik = 0.0;
        for i in 0..n {
            // Spread doc sweeps across the (virtual) clock.
            ps.pace(i, n);
            let idx = self.cursor % self.my_docs.len();
            self.cursor += 1;
            loglik += self.sweep_doc(ps, idx);
        }
        Some(loglik)
    }
}

/// Assemble and run an LDA experiment.
pub fn run_lda(
    cluster_cfg: ClusterConfig,
    lda_cfg: LdaConfig,
    clocks: u64,
) -> (RunReport, Arc<Corpus>) {
    lda_cfg.validate().expect("invalid LdaConfig");
    let corpus = Arc::new(Corpus::generate(&lda_cfg));
    let workers = cluster_cfg.workers;
    let mut cluster = Cluster::new(cluster_cfg);
    cluster.add_table(TableSpec::zeros(
        WT_TABLE,
        lda_cfg.vocab as RowId,
        lda_cfg.topics,
    ));
    cluster.add_table(TableSpec::zeros(TOPIC_TABLE, 1, lda_cfg.topics));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| Box::new(LdaWorker::new(corpus.clone(), w, workers)) as Box<dyn PsApp>)
        .collect();
    let report = cluster.run(apps, clocks);
    (report, corpus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::consistency::Consistency;

    fn tiny() -> LdaConfig {
        LdaConfig {
            vocab: 60,
            topics: 4,
            docs: 40,
            doc_len: 30,
            minibatch: 1.0,
            ..Default::default()
        }
    }

    fn run(consistency: Consistency, clocks: u64) -> (RunReport, Arc<Corpus>) {
        run_lda(
            ClusterConfig {
                workers: 2,
                shards: 2,
                consistency,
                ..Default::default()
            },
            tiny(),
            clocks,
        )
    }

    #[test]
    fn counts_conserved_bsp() {
        let (report, corpus) = run(Consistency::Bsp, 6);
        // Total word-topic count mass == total tokens (every token counted
        // exactly once, no update lost despite +/- churn).
        let mut total = 0.0f64;
        for w in 0..corpus.cfg.vocab as u64 {
            if let Some(row) = report.table_rows.get(&(WT_TABLE, w)) {
                total += row.iter().map(|&x| x as f64).sum::<f64>();
            }
        }
        assert!(
            (total - corpus.total_tokens() as f64).abs() < 1e-3,
            "mass {total} vs {} tokens",
            corpus.total_tokens()
        );
        // Topic totals must match too.
        let tt: f64 = report.table_rows[&(TOPIC_TABLE, 0)]
            .iter()
            .map(|&x| x as f64)
            .sum();
        assert!((tt - corpus.total_tokens() as f64).abs() < 1e-3);
    }

    #[test]
    fn counts_conserved_essp() {
        let (report, corpus) = run(Consistency::Essp { s: 2 }, 6);
        let tt: f64 = report.table_rows[&(TOPIC_TABLE, 0)]
            .iter()
            .map(|&x| x as f64)
            .sum();
        assert!((tt - corpus.total_tokens() as f64).abs() < 1e-3);
    }

    #[test]
    fn loglik_improves_with_sweeps() {
        let (report, _) = run(Consistency::Essp { s: 1 }, 12);
        let series = report.convergence.summed();
        assert!(series.len() >= 10);
        let early = series[1].value; // clock 1 = first real sweep
        let late = series.last().unwrap().value;
        assert!(
            late > early,
            "log-likelihood should ascend: {early} -> {late}"
        );
    }

    #[test]
    fn assignments_stay_in_range() {
        let corpus = Arc::new(Corpus::generate(&tiny()));
        let w = LdaWorker::new(corpus.clone(), 0, 2);
        assert!(w.my_docs.iter().all(|&d| d % 2 == 0));
    }
}
