//! Pure-rust reference implementation of the MF block gradient — the same
//! contract as the AOT kernel `mf_block_64x64x32`:
//!
//! ```text
//! E  = mask ⊙ (D − L·R)
//! dL = γ (E·Rᵀ − λL)
//! dR = γ (Lᵀ·E − λR)
//! ```
//!
//! Used (a) to cross-check the XLA path in integration tests, (b) as the
//! fast backend for consistency-model sweeps where the figure of interest
//! is staleness/convergence shape rather than kernel throughput.

/// Compute block deltas. All matrices row-major: `l` is (bm x k),
/// `r` is (k x bn), `d`/`mask` are (bm x bn). Returns (dl, dr, sq_loss,
/// obs_count).
pub fn block_grads(
    l: &[f32],
    r: &[f32],
    d: &[f32],
    mask: &[f32],
    bm: usize,
    bn: usize,
    k: usize,
    gamma: f32,
    lambda: f32,
) -> (Vec<f32>, Vec<f32>, f32, f32) {
    debug_assert_eq!(l.len(), bm * k);
    debug_assert_eq!(r.len(), k * bn);
    debug_assert_eq!(d.len(), bm * bn);
    debug_assert_eq!(mask.len(), bm * bn);

    // E = mask * (D - L @ R), computed tile-free (block fits in cache).
    let mut e = vec![0.0f32; bm * bn];
    let mut sq_loss = 0.0f32;
    let mut cnt = 0.0f32;
    for i in 0..bm {
        let li = &l[i * k..(i + 1) * k];
        for j in 0..bn {
            let m = mask[i * bn + j];
            if m == 0.0 {
                continue;
            }
            let mut dot = 0.0f32;
            for (kk, &lv) in li.iter().enumerate() {
                dot += lv * r[kk * bn + j];
            }
            let err = d[i * bn + j] - dot;
            e[i * bn + j] = err;
            sq_loss += err * err;
            cnt += m;
        }
    }

    // dL = gamma * (E @ R^T - lambda * L)
    let mut dl = vec![0.0f32; bm * k];
    for i in 0..bm {
        for kk in 0..k {
            let mut acc = 0.0f32;
            for j in 0..bn {
                acc += e[i * bn + j] * r[kk * bn + j];
            }
            dl[i * k + kk] = gamma * (acc - lambda * l[i * k + kk]);
        }
    }

    // dR = gamma * (L^T @ E - lambda * R)
    let mut dr = vec![0.0f32; k * bn];
    for kk in 0..k {
        for j in 0..bn {
            let mut acc = 0.0f32;
            for i in 0..bm {
                acc += l[i * k + kk] * e[i * bn + j];
            }
            dr[kk * bn + j] = gamma * (acc - lambda * r[kk * bn + j]);
        }
    }

    (dl, dr, sq_loss, cnt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| s * rng.normal_f32()).collect()
    }

    #[test]
    fn zero_mask_is_pure_shrinkage() {
        let mut rng = Rng::new(1);
        let (bm, bn, k) = (8, 8, 4);
        let l = randv(&mut rng, bm * k, 1.0);
        let r = randv(&mut rng, k * bn, 1.0);
        let d = randv(&mut rng, bm * bn, 1.0);
        let mask = vec![0.0; bm * bn];
        let (dl, dr, loss, cnt) = block_grads(&l, &r, &d, &mask, bm, bn, k, 0.1, 0.5);
        for (x, lv) in dl.iter().zip(&l) {
            assert!((x - (-0.1 * 0.5 * lv)).abs() < 1e-6);
        }
        for (x, rv) in dr.iter().zip(&r) {
            assert!((x - (-0.1 * 0.5 * rv)).abs() < 1e-6);
        }
        assert_eq!((loss, cnt), (0.0, 0.0));
    }

    #[test]
    fn gradient_direction_reduces_loss() {
        let mut rng = Rng::new(2);
        let (bm, bn, k) = (16, 16, 4);
        let lt = randv(&mut rng, bm * k, 0.5);
        let rt = randv(&mut rng, k * bn, 0.5);
        // D = Lt @ Rt exactly, full mask.
        let mut d = vec![0.0f32; bm * bn];
        for i in 0..bm {
            for j in 0..bn {
                for kk in 0..k {
                    d[i * bn + j] += lt[i * k + kk] * rt[kk * bn + j];
                }
            }
        }
        let mask = vec![1.0; bm * bn];
        let mut l = randv(&mut rng, bm * k, 0.3);
        let mut r = randv(&mut rng, k * bn, 0.3);
        let (_, _, loss0, _) = block_grads(&l, &r, &d, &mask, bm, bn, k, 0.01, 0.0);
        for _ in 0..200 {
            let (dl, dr, _, _) = block_grads(&l, &r, &d, &mask, bm, bn, k, 0.01, 0.0);
            for (a, x) in l.iter_mut().zip(&dl) {
                *a += x;
            }
            for (a, x) in r.iter_mut().zip(&dr) {
                *a += x;
            }
        }
        let (_, _, loss1, _) = block_grads(&l, &r, &d, &mask, bm, bn, k, 0.01, 0.0);
        assert!(loss1 < 0.1 * loss0, "{loss0} -> {loss1}");
    }

    #[test]
    fn finite_difference_check() {
        // Objective f = sum mask*(D-LR)^2 + lambda(|L|^2+|R|^2); our delta
        // is -gamma/2 * df (constants absorbed): check direction via f
        // decrease for small gamma on a single coordinate bump.
        let mut rng = Rng::new(3);
        let (bm, bn, k) = (4, 4, 2);
        let l = randv(&mut rng, bm * k, 0.5);
        let r = randv(&mut rng, k * bn, 0.5);
        let d = randv(&mut rng, bm * bn, 1.0);
        let mask: Vec<f32> = (0..bm * bn).map(|i| (i % 3 == 0) as u8 as f32).collect();
        let f = |l: &[f32], r: &[f32]| -> f64 {
            let mut tot = 0.0f64;
            for i in 0..bm {
                for j in 0..bn {
                    if mask[i * bn + j] == 0.0 {
                        continue;
                    }
                    let mut dot = 0.0f32;
                    for kk in 0..k {
                        dot += l[i * k + kk] * r[kk * bn + j];
                    }
                    tot += ((d[i * bn + j] - dot) as f64).powi(2);
                }
            }
            let lam = 0.1f64;
            tot + lam * (l.iter().map(|x| (x * x) as f64).sum::<f64>()
                + r.iter().map(|x| (x * x) as f64).sum::<f64>())
        };
        let (dl, dr, _, _) = block_grads(&l, &r, &d, &mask, bm, bn, k, 1.0, 0.1);
        // delta = -1/2 grad f. Finite-difference the full objective.
        let eps = 1e-3f32;
        for idx in [0usize, 3, 7] {
            let mut lp = l.clone();
            lp[idx] += eps;
            let mut lm = l.clone();
            lm[idx] -= eps;
            let fd = (f(&lp, &r) - f(&lm, &r)) / (2.0 * eps as f64);
            let analytic = -2.0 * dl[idx] as f64;
            assert!(
                (fd - analytic).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs {analytic}"
            );
        }
        let eps = 1e-3f32;
        for idx in [1usize, 5] {
            let mut rp = r.clone();
            rp[idx] += eps;
            let mut rm = r.clone();
            rm[idx] -= eps;
            let fd = (f(&l, &rp) - f(&l, &rm)) / (2.0 * eps as f64);
            let analytic = -2.0 * dr[idx] as f64;
            assert!(
                (fd - analytic).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs {analytic}"
            );
        }
    }
}
