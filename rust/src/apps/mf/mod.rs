//! Matrix Factorization via SGD on the parameter server — the paper's
//! first benchmark (Netflix, rank 100, 64 nodes; here a synthetic
//! Netflix-like matrix scaled to the testbed, see DESIGN.md §5).
//!
//! Both factor matrices live in the PS, as in the paper: table
//! [`L_TABLE`] holds the row factors (one PS row per matrix row, K floats),
//! table [`R_TABLE`] the column factors. Data is partitioned by row-blocks
//! across workers; each clock a worker processes a minibatch of dense
//! (64x64) blocks, computing deltas with either the AOT-compiled JAX+Pallas
//! kernel (`mf_block_64x64x32`, the production path) or a pure-rust
//! reference (`native`, used for tests and fast experiment sweeps).

pub mod data;
pub mod native;
pub mod train;

use crate::ps::types::TableId;

/// PS table holding L (one PS row per matrix row; K floats).
pub const L_TABLE: TableId = 0;
/// PS table holding R (one PS row per matrix column; K floats).
pub const R_TABLE: TableId = 1;

/// MF workload configuration.
#[derive(Debug, Clone)]
pub struct MfConfig {
    /// Matrix rows (multiple of `block`).
    pub rows: usize,
    /// Matrix cols (multiple of `block`).
    pub cols: usize,
    /// Factorization rank (must equal the artifact's K for the XLA path).
    pub rank: usize,
    /// Dense block edge (must equal the artifact's BM=BN for XLA).
    pub block: usize,
    /// Ground-truth rank used to synthesize the matrix.
    pub true_rank: usize,
    /// Observed entries per row (Netflix-like sparsity).
    pub nnz_per_row: usize,
    /// Observation noise stddev.
    pub noise: f32,
    /// SGD step size (absorbed constants, as in the paper).
    pub gamma: f32,
    /// L2 penalty.
    pub lambda: f32,
    /// Fraction of a worker's blocks processed per clock (the paper's
    /// "1% / 10% minibatch per Clock()").
    pub minibatch: f64,
    /// Init scale for L and R.
    pub init_scale: f32,
    pub seed: u64,
}

impl Default for MfConfig {
    fn default() -> Self {
        Self {
            rows: 512,
            cols: 512,
            rank: 32,
            block: 64,
            true_rank: 8,
            nnz_per_row: 48,
            noise: 0.05,
            gamma: 0.03,
            lambda: 0.05,
            minibatch: 0.25,
            init_scale: 0.1,
            seed: 7,
        }
    }
}

impl MfConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.rows % self.block == 0, "rows % block != 0");
        anyhow::ensure!(self.cols % self.block == 0, "cols % block != 0");
        anyhow::ensure!(self.nnz_per_row <= self.cols, "nnz_per_row > cols");
        anyhow::ensure!(self.rank > 0 && self.block > 0);
        Ok(())
    }

    pub fn row_blocks(&self) -> usize {
        self.rows / self.block
    }

    pub fn col_blocks(&self) -> usize {
        self.cols / self.block
    }
}
