//! Synthetic Netflix-like rating matrix (DESIGN.md §5 substitution).
//!
//! Ground truth: D* = U V^T with U, V drawn from scaled normals at
//! `true_rank`; observations are `nnz_per_row` uniformly sampled columns
//! per row with additive Gaussian noise. This preserves what matters for
//! the consistency-model experiments: SGD update sparsity pattern,
//! contention on R columns, and a recoverable low-rank signal whose squared
//! loss curve mirrors the paper's Netflix runs.

use super::MfConfig;
use crate::util::rng::Rng;

/// One observed entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub row: usize,
    pub col: usize,
    pub value: f32,
}

/// A dense (block x block) tile of observations.
#[derive(Debug, Clone)]
pub struct Block {
    /// Block-row / block-column indices.
    pub bi: usize,
    pub bj: usize,
    /// Row-major (block x block) values; 0 where unobserved.
    pub d: Vec<f32>,
    /// Row-major mask: 1.0 observed, 0.0 not.
    pub mask: Vec<f32>,
    pub nnz: usize,
}

/// The full synthetic dataset, pre-tiled into dense blocks.
#[derive(Debug)]
pub struct MfData {
    pub entries: Vec<Entry>,
    /// Blocks with nnz > 0, sorted by (bi, bj).
    pub blocks: Vec<Block>,
    pub cfg: MfConfig,
}

impl MfData {
    /// Generate the dataset (deterministic in cfg.seed).
    pub fn generate(cfg: &MfConfig) -> Self {
        cfg.validate().expect("invalid MfConfig");
        let mut rng = Rng::with_stream(cfg.seed, 0xDA7A);
        // Ground-truth factors.
        let scale = 1.0 / (cfg.true_rank as f32).sqrt();
        let u: Vec<f32> = (0..cfg.rows * cfg.true_rank)
            .map(|_| scale * rng.normal_f32())
            .collect();
        let v: Vec<f32> = (0..cfg.cols * cfg.true_rank)
            .map(|_| scale * rng.normal_f32())
            .collect();

        let mut entries = Vec::with_capacity(cfg.rows * cfg.nnz_per_row);
        let mut cols: Vec<usize> = (0..cfg.cols).collect();
        for row in 0..cfg.rows {
            // Sample distinct columns via partial shuffle.
            for i in 0..cfg.nnz_per_row {
                let j = i + rng.usize_below(cfg.cols - i);
                cols.swap(i, j);
            }
            for &col in &cols[..cfg.nnz_per_row] {
                let mut dot = 0.0f32;
                for k in 0..cfg.true_rank {
                    dot += u[row * cfg.true_rank + k] * v[col * cfg.true_rank + k];
                }
                entries.push(Entry {
                    row,
                    col,
                    value: dot + cfg.noise * rng.normal_f32(),
                });
            }
        }

        let blocks = tile(&entries, cfg);
        Self {
            entries,
            blocks,
            cfg: cfg.clone(),
        }
    }

    /// Blocks whose block-row is owned by `worker` out of `workers` (row
    /// blocks are striped across workers).
    pub fn blocks_for_worker(&self, worker: usize, workers: usize) -> Vec<&Block> {
        self.blocks
            .iter()
            .filter(|b| b.bi % workers == worker)
            .collect()
    }

    /// Global squared loss of factors L (rows x k) and R (cols x k), both
    /// row-major, over all observed entries — the paper's reported metric.
    pub fn sq_loss(&self, l: &[Vec<f32>], r: &[Vec<f32>]) -> f64 {
        let mut total = 0.0f64;
        for e in &self.entries {
            let dot: f32 = l[e.row]
                .iter()
                .zip(&r[e.col])
                .map(|(a, b)| a * b)
                .sum();
            let err = (e.value - dot) as f64;
            total += err * err;
        }
        total
    }
}

fn tile(entries: &[Entry], cfg: &MfConfig) -> Vec<Block> {
    let (rb, cb, b) = (cfg.row_blocks(), cfg.col_blocks(), cfg.block);
    let mut tiles: Vec<Option<Block>> = (0..rb * cb).map(|_| None).collect();
    for e in entries {
        let (bi, bj) = (e.row / b, e.col / b);
        let t = tiles[bi * cb + bj].get_or_insert_with(|| Block {
            bi,
            bj,
            d: vec![0.0; b * b],
            mask: vec![0.0; b * b],
            nnz: 0,
        });
        let idx = (e.row % b) * b + (e.col % b);
        t.d[idx] = e.value;
        t.mask[idx] = 1.0;
        t.nnz += 1;
    }
    tiles.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> MfConfig {
        MfConfig {
            rows: 128,
            cols: 128,
            nnz_per_row: 16,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic_generation() {
        let cfg = small_cfg();
        let a = MfData::generate(&cfg);
        let b = MfData::generate(&cfg);
        assert_eq!(a.entries.len(), b.entries.len());
        assert_eq!(a.entries[..50], b.entries[..50]);
    }

    #[test]
    fn entry_count_and_bounds() {
        let cfg = small_cfg();
        let d = MfData::generate(&cfg);
        assert_eq!(d.entries.len(), cfg.rows * cfg.nnz_per_row);
        assert!(d.entries.iter().all(|e| e.row < cfg.rows && e.col < cfg.cols));
    }

    #[test]
    fn distinct_columns_per_row() {
        let cfg = small_cfg();
        let d = MfData::generate(&cfg);
        for row in 0..cfg.rows {
            let mut cols: Vec<usize> = d
                .entries
                .iter()
                .filter(|e| e.row == row)
                .map(|e| e.col)
                .collect();
            let n = cols.len();
            cols.sort_unstable();
            cols.dedup();
            assert_eq!(cols.len(), n, "row {row} has duplicate columns");
        }
    }

    #[test]
    fn tiling_conserves_nnz() {
        let cfg = small_cfg();
        let d = MfData::generate(&cfg);
        let tiled: usize = d.blocks.iter().map(|b| b.nnz).sum();
        assert_eq!(tiled, d.entries.len());
        for blk in &d.blocks {
            let mask_nnz = blk.mask.iter().filter(|&&m| m == 1.0).count();
            assert_eq!(mask_nnz, blk.nnz);
        }
    }

    #[test]
    fn worker_striping_is_a_partition() {
        let cfg = small_cfg();
        let d = MfData::generate(&cfg);
        let p = 3;
        let total: usize = (0..p).map(|w| d.blocks_for_worker(w, p).len()).sum();
        assert_eq!(total, d.blocks.len());
    }

    #[test]
    fn ground_truth_factors_achieve_low_loss() {
        // The generative model itself must explain the data (sanity check
        // that sq_loss is wired correctly): random factors do much worse.
        let cfg = small_cfg();
        let d = MfData::generate(&cfg);
        let mut rng = Rng::new(3);
        let rand_l: Vec<Vec<f32>> = (0..cfg.rows)
            .map(|_| (0..cfg.rank).map(|_| 0.3 * rng.normal_f32()).collect())
            .collect();
        let rand_r: Vec<Vec<f32>> = (0..cfg.cols)
            .map(|_| (0..cfg.rank).map(|_| 0.3 * rng.normal_f32()).collect())
            .collect();
        let zero_l: Vec<Vec<f32>> = vec![vec![0.0; cfg.rank]; cfg.rows];
        let zero_r: Vec<Vec<f32>> = vec![vec![0.0; cfg.rank]; cfg.cols];
        // Zero factors => loss = sum of squared values > 0.
        let z = d.sq_loss(&zero_l, &zero_r);
        let r = d.sq_loss(&rand_l, &rand_r);
        assert!(z > 0.0);
        assert!(r > 0.0);
    }
}
