//! The MF worker: drives block-SGD against the PS under any consistency
//! model. One instance per worker thread; implements `PsApp`.

use std::sync::Arc;

use crate::ps::client::PsClient;
use crate::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use crate::ps::types::{Clock, RowId};
use crate::runtime::engine::{RuntimeHandle, Tensor};

use super::data::{Block, MfData};
use super::{native, MfConfig, L_TABLE, R_TABLE};

/// Compute backend for the block gradient.
#[derive(Clone)]
pub enum MfBackend {
    /// AOT-compiled JAX+Pallas kernel via PJRT (production path).
    Xla(RuntimeHandle),
    /// Pure-rust reference (tests, fast sweeps).
    Native,
}

/// The name of the AOT artifact the XLA path executes.
pub const MF_ARTIFACT: &str = "mf_block_64x64x32";

/// Per-worker MF trainer.
pub struct MfWorker {
    data: Arc<MfData>,
    backend: MfBackend,
    /// Indices into `my_blocks` processed round-robin.
    my_blocks: Vec<usize>,
    cursor: usize,
    cfg: MfConfig,
}

impl MfWorker {
    pub fn new(data: Arc<MfData>, worker: usize, workers: usize, backend: MfBackend) -> Self {
        let cfg = data.cfg.clone();
        let my_blocks: Vec<usize> = data
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.bi % workers == worker)
            .map(|(i, _)| i)
            .collect();
        Self {
            data,
            backend,
            my_blocks,
            cursor: 0,
            cfg,
        }
    }

    fn blocks_per_clock(&self) -> usize {
        ((self.my_blocks.len() as f64 * self.cfg.minibatch).ceil() as usize)
            .max(1)
            .min(self.my_blocks.len().max(1))
    }

    /// Process one block: GET factors, compute deltas, INC them back.
    /// Returns (sq_loss, nnz) measured pre-update.
    fn step_block(&self, ps: &mut PsClient, blk: &Block) -> (f64, f64) {
        let (b, k) = (self.cfg.block, self.cfg.rank);
        // GET L rows for this block-row and R columns for this block-col.
        // `with_row` borrows the cached snapshot in place: the assembly
        // copies straight out of the shared payload, no per-row Vec.
        let mut l = vec![0.0f32; b * k];
        for i in 0..b {
            ps.with_row((L_TABLE, (blk.bi * b + i) as RowId), |row| {
                l[i * k..(i + 1) * k].copy_from_slice(row);
            });
        }
        // R stored per matrix-column (K floats); assemble (k x b) row-major.
        let mut r = vec![0.0f32; k * b];
        for j in 0..b {
            ps.with_row((R_TABLE, (blk.bj * b + j) as RowId), |col| {
                for kk in 0..k {
                    r[kk * b + j] = col[kk];
                }
            });
        }

        let (dl, dr, loss, cnt) = match &self.backend {
            MfBackend::Native => native::block_grads(
                &l,
                &r,
                &blk.d,
                &blk.mask,
                b,
                b,
                k,
                self.cfg.gamma,
                self.cfg.lambda,
            ),
            MfBackend::Xla(rt) => {
                let out = rt
                    .execute(
                        MF_ARTIFACT,
                        vec![
                            Tensor::f32(vec![b, k], l),
                            Tensor::f32(vec![k, b], r),
                            Tensor::f32(vec![b, b], blk.d.clone()),
                            Tensor::f32(vec![b, b], blk.mask.clone()),
                            Tensor::f32(vec![2], vec![self.cfg.gamma, self.cfg.lambda]),
                        ],
                    )
                    .expect("mf kernel execution failed");
                let mut it = out.into_iter();
                let dl = it.next().unwrap().into_f32().unwrap();
                let dr = it.next().unwrap().into_f32().unwrap();
                let stats = it.next().unwrap().into_f32().unwrap();
                (dl, dr, stats[0], stats[1])
            }
        };

        // INC deltas back (coalesced client-side until CLOCK).
        for i in 0..b {
            ps.inc(
                (L_TABLE, (blk.bi * b + i) as RowId),
                &dl[i * k..(i + 1) * k],
            );
        }
        let mut col = vec![0.0f32; k];
        for j in 0..b {
            for kk in 0..k {
                col[kk] = dr[kk * b + j];
            }
            ps.inc((R_TABLE, (blk.bj * b + j) as RowId), &col);
        }
        (loss as f64, cnt as f64)
    }
}

impl PsApp for MfWorker {
    fn run_clock(&mut self, ps: &mut PsClient, _clock: Clock) -> Option<f64> {
        if self.my_blocks.is_empty() {
            return None;
        }
        let n = self.blocks_per_clock();
        let mut loss = 0.0;
        for i in 0..n {
            // Spread block processing across the (virtual) clock so reads
            // interleave with compute, as on a real cluster.
            ps.pace(i, n);
            let bidx = self.my_blocks[self.cursor % self.my_blocks.len()];
            self.cursor += 1;
            let blk = &self.data.blocks[bidx];
            let (l, _) = self.step_block(ps, blk);
            loss += l;
        }
        // Local metric: summed squared residuals of this clock's minibatch,
        // measured pre-update (the paper reports training squared loss).
        Some(loss)
    }
}

/// Assemble and run an MF experiment; returns the report and the dataset
/// (for final-loss evaluation).
pub fn run_mf(
    cluster_cfg: ClusterConfig,
    mf_cfg: MfConfig,
    clocks: u64,
    backend: MfBackend,
) -> (RunReport, Arc<MfData>) {
    mf_cfg.validate().expect("invalid MfConfig");
    let data = Arc::new(MfData::generate(&mf_cfg));
    let workers = cluster_cfg.workers;
    let mut cluster = Cluster::new(cluster_cfg);
    let init = mf_cfg.init_scale;
    cluster.add_table(TableSpec::random_normal(
        L_TABLE,
        mf_cfg.rows as RowId,
        mf_cfg.rank,
        init,
    ));
    cluster.add_table(TableSpec::random_normal(
        R_TABLE,
        mf_cfg.cols as RowId,
        mf_cfg.rank,
        init,
    ));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| Box::new(MfWorker::new(data.clone(), w, workers, backend.clone())) as Box<dyn PsApp>)
        .collect();
    let report = cluster.run(apps, clocks);
    (report, data)
}

/// Final global squared loss from a finished run's tables.
pub fn final_sq_loss(report: &RunReport, data: &MfData) -> f64 {
    let l = report.table_matrix(L_TABLE, data.cfg.rows as RowId, data.cfg.rank);
    let r = report.table_matrix(R_TABLE, data.cfg.cols as RowId, data.cfg.rank);
    data.sq_loss(&l, &r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::consistency::Consistency;

    fn tiny_cfg() -> MfConfig {
        MfConfig {
            rows: 128,
            cols: 128,
            rank: 8,
            block: 64,
            true_rank: 4,
            nnz_per_row: 24,
            noise: 0.01,
            gamma: 0.05,
            lambda: 0.01,
            minibatch: 1.0,
            ..Default::default()
        }
    }

    fn run(consistency: Consistency, clocks: u64) -> f64 {
        let ccfg = ClusterConfig {
            workers: 2,
            shards: 2,
            consistency,
            ..Default::default()
        };
        let mf = tiny_cfg();
        let (report, data) = run_mf(ccfg, mf, clocks, MfBackend::Native);
        final_sq_loss(&report, &data)
    }

    #[test]
    fn bsp_training_reduces_loss() {
        let before = {
            // 0 effective training: 1 clock at tiny step.
            run(Consistency::Bsp, 1)
        };
        let after = run(Consistency::Bsp, 30);
        assert!(
            after < 0.5 * before,
            "loss did not drop: {before} -> {after}"
        );
    }

    #[test]
    fn essp_training_reduces_loss() {
        let before = run(Consistency::Essp { s: 2 }, 1);
        let after = run(Consistency::Essp { s: 2 }, 30);
        assert!(after < 0.5 * before, "{before} -> {after}");
    }

    #[test]
    fn convergence_metric_reported_each_clock() {
        let ccfg = ClusterConfig {
            workers: 2,
            shards: 1,
            consistency: Consistency::Ssp { s: 1 },
            ..Default::default()
        };
        let (report, _) = run_mf(ccfg, tiny_cfg(), 5, MfBackend::Native);
        assert_eq!(report.convergence.summed().len(), 5);
        // Loss curve should be non-increasing-ish: last < first.
        let s = report.convergence.summed();
        assert!(s.last().unwrap().value < s.first().unwrap().value);
    }

    #[test]
    fn worker_block_ownership_partitions() {
        let data = Arc::new(MfData::generate(&tiny_cfg()));
        let w0 = MfWorker::new(data.clone(), 0, 2, MfBackend::Native);
        let w1 = MfWorker::new(data.clone(), 1, 2, MfBackend::Native);
        assert_eq!(
            w0.my_blocks.len() + w1.my_blocks.len(),
            data.blocks.len()
        );
    }
}
