//! L2-regularized logistic regression via SGD on the PS.
//!
//! Not in the paper's evaluation, but a one-table, one-row workload that
//! (a) demonstrates the general-purpose claim — a third algorithm runs
//! unchanged on every consistency model — and (b) gives the property tests
//! a convex, single-parameter-vector workload where BSP equivalence and
//! staleness effects are easy to reason about.

use std::sync::Arc;

use crate::ps::client::PsClient;
use crate::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use crate::ps::types::{Clock, TableId};
use crate::util::rng::Rng;

/// PS table: a single row holding the weight vector (dim + 1 with bias).
pub const W_TABLE: TableId = 30;

#[derive(Debug, Clone)]
pub struct LogRegConfig {
    pub dim: usize,
    pub examples: usize,
    /// Margin scale of the synthetic separator.
    pub margin: f32,
    /// Label-noise rate.
    pub flip: f64,
    pub lr: f32,
    pub lambda: f32,
    /// Examples per worker per clock.
    pub batch: usize,
    pub seed: u64,
}

impl Default for LogRegConfig {
    fn default() -> Self {
        Self {
            dim: 32,
            examples: 2000,
            margin: 2.0,
            flip: 0.02,
            lr: 0.1,
            lambda: 1e-4,
            batch: 64,
            seed: 21,
        }
    }
}

/// Synthetic linearly-separable-with-noise dataset.
pub struct LogRegData {
    pub xs: Vec<Vec<f32>>,
    pub ys: Vec<f32>, // +-1
    pub cfg: LogRegConfig,
}

impl LogRegData {
    pub fn generate(cfg: &LogRegConfig) -> Self {
        let mut rng = Rng::with_stream(cfg.seed, 0x106e9);
        let w_true: Vec<f32> = (0..cfg.dim).map(|_| rng.normal_f32()).collect();
        let norm: f32 = w_true.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut xs = Vec::with_capacity(cfg.examples);
        let mut ys = Vec::with_capacity(cfg.examples);
        for _ in 0..cfg.examples {
            let x: Vec<f32> = (0..cfg.dim).map(|_| rng.normal_f32()).collect();
            let score: f32 =
                x.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f32>() / norm * cfg.margin;
            let mut y = if score >= 0.0 { 1.0 } else { -1.0 };
            if rng.f64() < cfg.flip {
                y = -y;
            }
            xs.push(x);
            ys.push(y);
        }
        Self {
            xs,
            ys,
            cfg: cfg.clone(),
        }
    }

    /// Mean log-loss of weights `w` (with bias at the end) over all data.
    pub fn log_loss(&self, w: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for (x, &y) in self.xs.iter().zip(&self.ys) {
            let z: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() + w[self.cfg.dim];
            total += (1.0 + (-(y * z) as f64).exp()).ln();
        }
        total / self.xs.len() as f64
    }

    pub fn accuracy(&self, w: &[f32]) -> f64 {
        let mut correct = 0usize;
        for (x, &y) in self.xs.iter().zip(&self.ys) {
            let z: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() + w[self.cfg.dim];
            if z * y > 0.0 {
                correct += 1;
            }
        }
        correct as f64 / self.xs.len() as f64
    }
}

/// Per-worker SGD trainer.
pub struct LogRegWorker {
    data: Arc<LogRegData>,
    my_examples: Vec<usize>,
    cursor: usize,
    cfg: LogRegConfig,
    /// Reusable weight buffer: the inner loop reads via `get_into`, so
    /// steady-state clocks allocate nothing for the GET.
    w_buf: Vec<f32>,
}

impl LogRegWorker {
    pub fn new(data: Arc<LogRegData>, worker: usize, workers: usize) -> Self {
        let cfg = data.cfg.clone();
        let my_examples = (0..data.xs.len()).filter(|i| i % workers == worker).collect();
        Self {
            data,
            my_examples,
            cursor: 0,
            cfg,
            w_buf: Vec::new(),
        }
    }
}

impl PsApp for LogRegWorker {
    fn run_clock(&mut self, ps: &mut PsClient, _clock: Clock) -> Option<f64> {
        let mut w = std::mem::take(&mut self.w_buf);
        ps.get_into((W_TABLE, 0), &mut w);
        let dim = self.cfg.dim;
        let mut grad = vec![0.0f32; dim + 1];
        let mut loss = 0.0f64;
        let n = self.cfg.batch.min(self.my_examples.len());
        for _ in 0..n {
            let idx = self.my_examples[self.cursor % self.my_examples.len()];
            self.cursor += 1;
            let (x, y) = (&self.data.xs[idx], self.data.ys[idx]);
            let z: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() + w[dim];
            let margin = (y * z) as f64;
            loss += (1.0 + (-margin).exp()).ln();
            // d/dw logloss = -sigmoid(-y z) * y * x
            let coef = -(1.0 / (1.0 + margin.exp())) as f32 * y;
            for (g, xv) in grad.iter_mut().zip(x) {
                *g += coef * xv;
            }
            grad[dim] += coef;
        }
        let scale = -self.cfg.lr / n as f32;
        let mut delta: Vec<f32> = grad.iter().map(|g| g * scale).collect();
        for (d, wv) in delta.iter_mut().zip(&w) {
            *d -= self.cfg.lr * self.cfg.lambda * wv;
        }
        ps.inc((W_TABLE, 0), &delta);
        self.w_buf = w;
        Some(loss / n as f64)
    }
}

/// Assemble and run a logistic-regression experiment.
pub fn run_logreg(
    cluster_cfg: ClusterConfig,
    cfg: LogRegConfig,
    clocks: u64,
) -> (RunReport, Arc<LogRegData>) {
    let data = Arc::new(LogRegData::generate(&cfg));
    let workers = cluster_cfg.workers;
    let mut cluster = Cluster::new(cluster_cfg);
    cluster.add_table(TableSpec::zeros(W_TABLE, 1, cfg.dim + 1));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| Box::new(LogRegWorker::new(data.clone(), w, workers)) as Box<dyn PsApp>)
        .collect();
    let report = cluster.run(apps, clocks);
    (report, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::consistency::Consistency;

    #[test]
    fn data_is_mostly_separable() {
        let data = LogRegData::generate(&LogRegConfig::default());
        assert_eq!(data.xs.len(), 2000);
        // Zero weights: 50% accuracy, loss ln 2.
        let w0 = vec![0.0f32; 33];
        assert!((data.log_loss(&w0) - (2.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn trains_to_high_accuracy_essp() {
        let (report, data) = run_logreg(
            ClusterConfig {
                workers: 4,
                shards: 1,
                consistency: Consistency::Essp { s: 1 },
                ..Default::default()
            },
            LogRegConfig::default(),
            40,
        );
        let w = &report.table_rows[&(W_TABLE, 0)];
        let acc = data.accuracy(w);
        assert!(acc > 0.9, "accuracy {acc}");
        let loss = data.log_loss(w);
        assert!(loss < 0.35, "loss {loss}");
    }

    #[test]
    fn loss_curve_monotoneish() {
        let (report, _) = run_logreg(
            ClusterConfig {
                workers: 2,
                shards: 1,
                consistency: Consistency::Bsp,
                ..Default::default()
            },
            LogRegConfig::default(),
            60,
        );
        let s = report.convergence.summed();
        assert!(
            s.last().unwrap().value < 0.6 * s.first().unwrap().value,
            "{} -> {}",
            s.first().unwrap().value,
            s.last().unwrap().value
        );
    }
}
