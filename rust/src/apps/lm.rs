//! Data-parallel transformer LM pretraining through the PS — the
//! end-to-end driver workload (DESIGN.md §8). Proves all layers compose:
//! the AOT artifact (L2 JAX transformer + L1 Pallas fused cross-entropy)
//! executes under the rust runtime, parameters live in PS rows, gradients
//! flow back as INCs under any consistency model.
//!
//! PS layout: one PS row per parameter tensor (row length = element
//! count), ordered exactly as `artifacts/meta.json` records (`params`),
//! which mirrors `python/compile/transformer.py::param_spec`.

use std::sync::Arc;

use anyhow::Result;

use crate::ps::client::PsClient;
use crate::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use crate::ps::types::{Clock, RowId, TableId};
use crate::runtime::artifact::{ArtifactMeta, ParamSpec};
use crate::runtime::engine::{RuntimeHandle, Tensor};
use crate::util::rng::Rng;

/// PS table holding the LM parameters (row r = tensor r in meta order).
pub const PARAM_TABLE: TableId = 20;

/// LM training configuration.
#[derive(Debug, Clone)]
pub struct LmTrainConfig {
    /// AOT artifact to execute (e.g. "lm_step_gpt-tiny").
    pub artifact: String,
    /// Base learning rate; the effective step is lr / sqrt(1 + t/decay).
    pub lr: f32,
    /// Step-size decay horizon in clocks (paper-style 1/sqrt(t) schedule).
    pub lr_decay: f64,
    /// Synthetic-corpus seed.
    pub seed: u64,
    /// Bigram branching factor of the synthetic corpus (entropy knob):
    /// each token has this many likely successors, so the achievable loss
    /// floor is ~ln(branch).
    pub branch: usize,
}

impl Default for LmTrainConfig {
    fn default() -> Self {
        Self {
            artifact: "lm_step_gpt-tiny".into(),
            lr: 0.12,
            lr_decay: 200.0,
            seed: 5,
            branch: 4,
        }
    }
}

/// Synthetic token stream: a random sparse bigram chain. Learnable
/// structure with a known entropy floor (~ln(branch)), no external data.
pub struct BigramStream {
    successors: Arc<Vec<Vec<u32>>>,
    state: u32,
    rng: Rng,
}

impl BigramStream {
    /// Build the shared successor table (deterministic in seed).
    pub fn build_table(vocab: usize, branch: usize, seed: u64) -> Arc<Vec<Vec<u32>>> {
        let mut rng = Rng::with_stream(seed, 0xb16a);
        Arc::new(
            (0..vocab)
                .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as u32).collect())
                .collect(),
        )
    }

    pub fn new(successors: Arc<Vec<Vec<u32>>>, worker: usize, seed: u64) -> Self {
        let mut rng = Rng::with_stream(seed ^ 0x57e4, worker as u64);
        let state = rng.below(successors.len() as u64) as u32;
        Self {
            successors,
            state,
            rng,
        }
    }

    pub fn next_token(&mut self) -> u32 {
        let succ = &self.successors[self.state as usize];
        self.state = succ[self.rng.usize_below(succ.len())];
        self.state
    }

    /// Fill a (batch, seq) token block and its next-token targets.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut cur = self.next_token();
            for _ in 0..seq {
                tokens.push(cur as i32);
                let nxt = self.next_token();
                targets.push(nxt as i32);
                cur = nxt;
            }
        }
        (tokens, targets)
    }
}

/// Per-worker LM trainer.
pub struct LmWorker {
    rt: RuntimeHandle,
    cfg: LmTrainConfig,
    params: Vec<ParamSpec>,
    batch: usize,
    seq: usize,
    stream: BigramStream,
}

impl LmWorker {
    pub fn new(
        rt: RuntimeHandle,
        cfg: LmTrainConfig,
        meta: &ArtifactMeta,
        worker: usize,
    ) -> Self {
        let lm = meta
            .lm_config
            .as_ref()
            .expect("artifact has no lm_config");
        let params = meta.params.clone().expect("artifact has no params");
        let table = BigramStream::build_table(lm.vocab, cfg.branch, cfg.seed);
        let stream = BigramStream::new(table, worker, cfg.seed);
        Self {
            rt,
            cfg,
            params,
            batch: lm.batch,
            seq: lm.seq,
            stream,
        }
    }

    fn lr_at(&self, clock: Clock) -> f32 {
        (self.cfg.lr as f64 / (1.0 + clock as f64 / self.cfg.lr_decay).sqrt()) as f32
    }
}

impl PsApp for LmWorker {
    fn run_clock(&mut self, ps: &mut PsClient, clock: Clock) -> Option<f64> {
        // Assemble inputs: tokens, targets, then every param row.
        let (tokens, targets) = self.stream.batch(self.batch, self.seq);
        let mut inputs = Vec::with_capacity(2 + self.params.len());
        inputs.push(Tensor::i32(vec![self.batch, self.seq], tokens));
        inputs.push(Tensor::i32(vec![self.batch, self.seq], targets));
        for (r, spec) in self.params.iter().enumerate() {
            let row = ps.get((PARAM_TABLE, r as RowId));
            debug_assert_eq!(row.len(), spec.elements(), "param row {} length", spec.name);
            inputs.push(Tensor::f32(spec.shape.clone(), row));
        }
        let outputs = self
            .rt
            .execute(&self.cfg.artifact, inputs)
            .expect("lm step execution failed");
        let mut it = outputs.into_iter();
        let loss = it.next().unwrap().into_f32().unwrap()[0] as f64;
        // Apply SGD via additive INC: delta = -lr * grad.
        let lr = self.lr_at(clock);
        for (r, grad) in it.enumerate() {
            let mut g = grad.into_f32().unwrap();
            for x in &mut g {
                *x *= -lr;
            }
            ps.inc((PARAM_TABLE, r as RowId), &g);
        }
        Some(loss)
    }
}

/// Initialize the parameter table to match `transformer.init_params`-style
/// scales: unit gains, zero biases, scaled normals for weights (the exact
/// python init need not be replicated bit-for-bit; scale parity is what
/// matters for trainability).
pub fn param_table_spec(params: &[ParamSpec], seed: u64) -> TableSpec {
    let specs: Vec<ParamSpec> = params.to_vec();
    let row_len = 0; // variable-length rows: validated per-row below
    let _ = row_len;
    let max_len = specs.iter().map(|p| p.elements()).max().unwrap_or(0);
    let _ = max_len;
    let specs2 = specs.clone();
    TableSpec {
        table: PARAM_TABLE,
        rows: specs.len() as RowId,
        row_len: usize::MAX, // sentinel: variable-length (validated below)
        init: Box::new(move |r, rng| init_param(&specs2[r as usize], rng, seed)),
    }
}

fn init_param(spec: &ParamSpec, rng: &mut Rng, _seed: u64) -> Vec<f32> {
    let n = spec.elements();
    let name = spec.name.as_str();
    if name.ends_with("_g") {
        vec![1.0; n]
    } else if name.ends_with("_b") || name.ends_with(".b1") || name.ends_with(".b2") {
        vec![0.0; n]
    } else {
        let fan_in = spec.shape.first().copied().unwrap_or(1) as f32;
        let scale = if name.contains("emb") {
            0.02
        } else {
            1.0 / fan_in.sqrt()
        };
        (0..n).map(|_| scale * rng.normal_f32()).collect()
    }
}

/// Assemble and run an LM pretraining experiment.
pub fn run_lm(
    cluster_cfg: ClusterConfig,
    train_cfg: LmTrainConfig,
    meta: &ArtifactMeta,
    rt: RuntimeHandle,
    clocks: u64,
) -> Result<RunReport> {
    let params = meta
        .params
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("artifact {} has no params", meta.name))?;
    rt.preload(&train_cfg.artifact)?;
    let workers = cluster_cfg.workers;
    let mut cluster = Cluster::new(cluster_cfg);
    cluster.add_table(param_table_spec(params, train_cfg.seed));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(LmWorker::new(rt.clone(), train_cfg.clone(), meta, w)) as Box<dyn PsApp>
        })
        .collect();
    Ok(cluster.run(apps, clocks))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bigram_stream_deterministic_and_in_vocab() {
        let table = BigramStream::build_table(64, 4, 9);
        let mut a = BigramStream::new(table.clone(), 0, 9);
        let mut b = BigramStream::new(table.clone(), 0, 9);
        for _ in 0..100 {
            let (x, y) = (a.next_token(), b.next_token());
            assert_eq!(x, y);
            assert!(x < 64);
        }
    }

    #[test]
    fn workers_get_different_streams() {
        let table = BigramStream::build_table(64, 4, 9);
        let mut a = BigramStream::new(table.clone(), 0, 9);
        let mut b = BigramStream::new(table, 1, 9);
        let xs: Vec<u32> = (0..32).map(|_| a.next_token()).collect();
        let ys: Vec<u32> = (0..32).map(|_| b.next_token()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn batch_targets_are_shifted_tokens() {
        let table = BigramStream::build_table(64, 4, 9);
        let mut s = BigramStream::new(table, 0, 9);
        let (tokens, targets) = s.batch(2, 8);
        assert_eq!(tokens.len(), 16);
        assert_eq!(targets.len(), 16);
        // Within a row, target[t] == token[t+1].
        for row in 0..2 {
            for t in 0..7 {
                assert_eq!(targets[row * 8 + t], tokens[row * 8 + t + 1]);
            }
        }
    }

    #[test]
    fn bigram_chain_follows_successor_table() {
        let table = BigramStream::build_table(64, 4, 9);
        let mut s = BigramStream::new(table.clone(), 0, 9);
        let mut prev = s.next_token();
        for _ in 0..200 {
            let next = s.next_token();
            assert!(
                table[prev as usize].contains(&next),
                "{next} not a successor of {prev}"
            );
            prev = next;
        }
    }

    #[test]
    fn init_param_scales() {
        let mut rng = Rng::new(0);
        let g = init_param(
            &ParamSpec {
                name: "l0.ln1_g".into(),
                shape: vec![8],
            },
            &mut rng,
            0,
        );
        assert_eq!(g, vec![1.0; 8]);
        let b = init_param(
            &ParamSpec {
                name: "l0.b1".into(),
                shape: vec![8],
            },
            &mut rng,
            0,
        );
        assert_eq!(b, vec![0.0; 8]);
        let w = init_param(
            &ParamSpec {
                name: "l0.wqkv".into(),
                shape: vec![16, 48],
            },
            &mut rng,
            0,
        );
        let rms = (w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32).sqrt();
        assert!((rms - 0.25).abs() < 0.05, "rms {rms}"); // 1/sqrt(16)
    }
}
