//! Experiment harness: one driver per paper table/figure (DESIGN.md §4).
//!
//! Each driver runs the workload across the consistency models the paper
//! compares, writes the regenerating CSV under `results/`, and returns a
//! summary that `main.rs` prints as the paper's rows/series. Absolute
//! numbers differ from the paper (simulated substrate); the *shape* — who
//! wins, by what factor, where divergence sets in — is the reproduction
//! target.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Result;

use crate::apps::lda::gibbs::run_lda;
use crate::apps::lda::LdaConfig;
use crate::apps::mf::train::{final_sq_loss, run_mf, MfBackend};
use crate::apps::mf::MfConfig;
use crate::metrics::convergence::Sample;
use crate::metrics::export;
use crate::ps::consistency::Consistency;
use crate::ps::failover::FailoverConfig;
use crate::ps::server::{ClusterConfig, RunReport};
use crate::sim::net::NetConfig;
use crate::sim::straggler::StragglerModel;
use crate::transport::TransportSel;

/// Common experiment options (from the CLI).
#[derive(Debug, Clone)]
pub struct ExpOpts {
    pub workers: usize,
    pub shards: usize,
    pub seed: u64,
    pub clocks: u64,
    pub out_dir: PathBuf,
    /// Straggler injection shared by all runs of an experiment.
    pub straggler: StragglerModel,
    /// Network profile ("lan" with delays, or "instant").
    pub lan: bool,
    /// Data plane: the simulated router or real loopback TCP (over TCP
    /// the modeled lan delays do not apply — the sockets are the network).
    pub transport: TransportSel,
    /// Virtual per-clock compute duration (ms); 0 = raw speed. The paper's
    /// regime — long uniform compute per clock — needs this on a
    /// timeshared testbed (see ClusterConfig::virtual_clock).
    pub virtual_clock_ms: u64,
    /// Replica shards per primary (0 = none): hot-read fan-out for the
    /// pull-admission models (see ClusterConfig::replicas).
    pub replicas: usize,
    /// Failure-detector tuning for runs that inject shard deaths
    /// (`--heartbeat-every` / `--suspect-after` / `--re-replicate`).
    pub failover: FailoverConfig,
    /// Idle spare nodes provisioned for re-replication targets.
    pub spare_nodes: usize,
    /// Client resend window (clocks of buffered deltas replayed into a
    /// WAL-recovered spare after an unreplicated primary death).
    pub resend_window: i64,
}

impl Default for ExpOpts {
    fn default() -> Self {
        Self {
            workers: 8,
            shards: 4,
            seed: 42,
            clocks: 60,
            out_dir: PathBuf::from("results"),
            straggler: StragglerModel::RandomUniform { max_factor: 3.0 },
            lan: true,
            transport: TransportSel::Sim,
            virtual_clock_ms: 25,
            replicas: 0,
            failover: FailoverConfig::default(),
            spare_nodes: 0,
            resend_window: 0,
        }
    }
}

impl ExpOpts {
    pub fn cluster(&self, consistency: Consistency) -> ClusterConfig {
        ClusterConfig {
            workers: self.workers,
            shards: self.shards,
            active_shards: 0,
            replicas: self.replicas,
            migration: None,
            consistency,
            net: if self.lan {
                NetConfig::lan(self.seed)
            } else {
                NetConfig::instant()
            },
            straggler: self.straggler.clone(),
            cache_capacity: 0,
            read_my_writes: true,
            virtual_clock: (self.virtual_clock_ms > 0)
                .then(|| Duration::from_millis(self.virtual_clock_ms)),
            transport: self.transport,
            deterministic: false,
            seed: self.seed,
            failover: self.failover.clone(),
            spare_nodes: self.spare_nodes,
            resend_window: self.resend_window,
            ..ClusterConfig::default()
        }
    }

    pub fn out(&self, name: &str) -> PathBuf {
        self.out_dir.join(name)
    }
}

/// One labeled run result used by summaries.
pub struct LabeledRun {
    pub label: String,
    pub report: RunReport,
    pub final_value: f64,
}

// ---------------------------------------------------------------- FIG1-L

/// Fig. 1 (left): empirical staleness distribution, MF on SSP vs ESSP.
pub fn fig1_staleness(opts: &ExpOpts, mf: MfConfig, s: i64) -> Result<Vec<LabeledRun>> {
    let mut runs = Vec::new();
    for consistency in [Consistency::Ssp { s }, Consistency::Essp { s }] {
        let (report, data) = run_mf(
            opts.cluster(consistency),
            mf.clone(),
            opts.clocks,
            MfBackend::Native,
        );
        let final_value = final_sq_loss(&report, &data);
        let label = consistency.label();
        export::staleness_csv(
            &opts.out(&format!("fig1_staleness_{}.csv", label.replace(':', "_"))),
            &label,
            &report.staleness,
        )?;
        runs.push(LabeledRun {
            label,
            report,
            final_value,
        });
    }
    // Combined CSV matching the figure's two series.
    let mut rows = Vec::new();
    for run in &runs {
        let total = run.report.staleness.total().max(1) as f64;
        for (d, c) in run.report.staleness.buckets() {
            rows.push(vec![
                run.label.clone(),
                d.to_string(),
                c.to_string(),
                format!("{:.6}", c as f64 / total),
            ]);
        }
    }
    export::write_csv(
        &opts.out("fig1_staleness.csv"),
        &["label", "differential", "count", "fraction"],
        &rows,
    )?;
    Ok(runs)
}

// ---------------------------------------------------------------- FIG1-R

/// Fig. 1 (right): communication vs computation breakdown, LDA across
/// staleness values, SSP vs ESSP.
pub fn fig1_breakdown(
    opts: &ExpOpts,
    lda: LdaConfig,
    staleness: &[i64],
) -> Result<Vec<(String, f64, f64, f64)>> {
    // (label, comp_s, comm_s, comm_fraction)
    let mut out = Vec::new();
    for &s in staleness {
        for consistency in [Consistency::Ssp { s }, Consistency::Essp { s }] {
            let (report, _) = run_lda(opts.cluster(consistency), lda.clone(), opts.clocks);
            let comp: f64 = report
                .timelines
                .iter()
                .map(|t| t.total_comp().as_secs_f64())
                .sum();
            let comm: f64 = report
                .timelines
                .iter()
                .map(|t| t.total_comm().as_secs_f64())
                .sum();
            out.push((
                consistency.label(),
                comp,
                comm,
                report.comm_fraction(),
            ));
        }
    }
    let rows: Vec<Vec<String>> = out
        .iter()
        .map(|(l, comp, comm, frac)| {
            vec![
                l.clone(),
                format!("{comp:.4}"),
                format!("{comm:.4}"),
                format!("{frac:.4}"),
            ]
        })
        .collect();
    export::write_csv(
        &opts.out("fig1_breakdown.csv"),
        &["label", "comp_seconds", "comm_seconds", "comm_fraction"],
        &rows,
    )?;
    Ok(out)
}

// ------------------------------------------------------------------ FIG2

/// The consistency set Fig. 2 compares at a given staleness list:
/// BSP plus SSP/ESSP at each s.
pub fn fig2_models(staleness: &[i64]) -> Vec<Consistency> {
    let mut v = vec![Consistency::Bsp];
    for &s in staleness {
        v.push(Consistency::Ssp { s });
        v.push(Consistency::Essp { s });
    }
    v
}

/// Fig. 2 (MF): squared-loss convergence per iteration and per second.
pub fn fig2_mf(opts: &ExpOpts, mf: MfConfig, staleness: &[i64]) -> Result<Vec<LabeledRun>> {
    let mut runs = Vec::new();
    let mut series: Vec<(String, Vec<Sample>)> = Vec::new();
    for consistency in fig2_models(staleness) {
        let (report, data) = run_mf(
            opts.cluster(consistency),
            mf.clone(),
            opts.clocks,
            MfBackend::Native,
        );
        let final_value = final_sq_loss(&report, &data);
        series.push((consistency.label(), report.convergence.summed()));
        runs.push(LabeledRun {
            label: consistency.label(),
            report,
            final_value,
        });
    }
    export::convergence_csv(&opts.out("fig2_mf.csv"), &series)?;
    Ok(runs)
}

/// Fig. 2 (LDA): log-likelihood convergence per iteration and per second.
pub fn fig2_lda(opts: &ExpOpts, lda: LdaConfig, staleness: &[i64]) -> Result<Vec<LabeledRun>> {
    let mut runs = Vec::new();
    let mut series: Vec<(String, Vec<Sample>)> = Vec::new();
    for consistency in fig2_models(staleness) {
        let (report, _) = run_lda(opts.cluster(consistency), lda.clone(), opts.clocks);
        let final_value = report.convergence.last_value().unwrap_or(f64::NAN);
        series.push((consistency.label(), report.convergence.summed()));
        runs.push(LabeledRun {
            label: consistency.label(),
            report,
            final_value,
        });
    }
    export::convergence_csv(&opts.out("fig2_lda.csv"), &series)?;
    Ok(runs)
}

// ------------------------------------------------------------- ROBUSTNESS

/// §Robustness: MF at aggressive step sizes across staleness; SSP should
/// destabilize/diverge at high staleness while ESSP stays stable.
pub struct RobustnessRow {
    pub label: String,
    pub gamma: f32,
    pub final_loss: f64,
    pub diverged: bool,
}

pub fn robustness(
    opts: &ExpOpts,
    mf_base: MfConfig,
    gammas: &[f32],
    staleness: &[i64],
) -> Result<Vec<RobustnessRow>> {
    let mut rows = Vec::new();
    // Reference scale: loss with zero training (initial factors).
    let (report0, data0) = run_mf(
        opts.cluster(Consistency::Bsp),
        MfConfig {
            gamma: 0.0,
            ..mf_base.clone()
        },
        1,
        MfBackend::Native,
    );
    let initial_loss = final_sq_loss(&report0, &data0);
    for &gamma in gammas {
        for &s in staleness {
            for consistency in [Consistency::Ssp { s }, Consistency::Essp { s }] {
                let mf = MfConfig {
                    gamma,
                    ..mf_base.clone()
                };
                let (report, data) = run_mf(
                    opts.cluster(consistency),
                    mf,
                    opts.clocks,
                    MfBackend::Native,
                );
                let final_loss = final_sq_loss(&report, &data);
                let diverged = !final_loss.is_finite() || final_loss > 2.0 * initial_loss;
                rows.push(RobustnessRow {
                    label: consistency.label(),
                    gamma,
                    final_loss,
                    diverged,
                });
            }
        }
    }
    let csv: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{}", r.gamma),
                format!("{:.4}", r.final_loss),
                r.diverged.to_string(),
            ]
        })
        .collect();
    export::write_csv(
        &opts.out("robustness.csv"),
        &["label", "gamma", "final_loss", "diverged"],
        &csv,
    )?;
    Ok(rows)
}

// ------------------------------------------------------------------- VAP

pub struct VapRow {
    pub label: String,
    pub wall: Duration,
    pub final_loss: f64,
    pub stall: Duration,
    pub stalled_reads: u64,
}

/// §VAP: enforceable only with global synchronization — measure the read
/// stalls VAP induces at various v0 against ESSP on the same workload.
pub fn vap_compare(opts: &ExpOpts, mf: MfConfig, v0s: &[f32], s: i64) -> Result<Vec<VapRow>> {
    let mut rows = Vec::new();
    let mut do_run = |consistency: Consistency| {
        let (report, data) = run_mf(
            opts.cluster(consistency),
            mf.clone(),
            opts.clocks,
            MfBackend::Native,
        );
        let final_loss = final_sq_loss(&report, &data);
        let (stall, stalled_reads) = report.vap_stall.unwrap_or((Duration::ZERO, 0));
        rows.push(VapRow {
            label: consistency.label(),
            wall: report.wall,
            final_loss,
            stall,
            stalled_reads,
        });
    };
    do_run(Consistency::Essp { s });
    for &v0 in v0s {
        do_run(Consistency::Vap { v0 });
    }
    let csv: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.label.clone(),
                format!("{:.4}", r.wall.as_secs_f64()),
                format!("{:.4}", r.final_loss),
                format!("{:.4}", r.stall.as_secs_f64()),
                r.stalled_reads.to_string(),
            ]
        })
        .collect();
    export::write_csv(
        &opts.out("vap_compare.csv"),
        &["label", "wall_seconds", "final_loss", "stall_seconds", "stalled_reads"],
        &csv,
    )?;
    Ok(rows)
}

/// Write the merged staleness summary JSON used by EXPERIMENTS.md.
pub fn write_staleness_summary(path: &Path, runs: &[LabeledRun]) -> Result<()> {
    use crate::util::json::{arr, Json};
    let items: Vec<Json> = runs
        .iter()
        .map(|r| export::staleness_summary(&r.label, &r.report.staleness))
        .collect();
    export::write_json(path, &arr(items))
}
