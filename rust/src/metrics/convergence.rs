//! Convergence curves — the Fig. 2 instrument.
//!
//! Workers report a per-clock local metric (e.g. summed squared residuals
//! for MF, token log-likelihood for LDA); the harness aggregates across
//! workers per clock, yielding (clock, wall-seconds, value) series plotted
//! against both axes as in the paper.

use std::collections::BTreeMap;

use crate::ps::types::Clock;

/// One aggregated sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub clock: Clock,
    /// Seconds since run start at which the *last* worker reported this
    /// clock (i.e. when the aggregate became complete).
    pub seconds: f64,
    pub value: f64,
}

/// Aggregates per-worker per-clock metric reports.
#[derive(Debug, Default, Clone)]
pub struct ConvergenceLog {
    /// clock -> (sum, n_reports, latest_seconds)
    acc: BTreeMap<Clock, (f64, usize, f64)>,
}

impl ConvergenceLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn report(&mut self, clock: Clock, seconds: f64, value: f64) {
        let e = self.acc.entry(clock).or_insert((0.0, 0, 0.0));
        e.0 += value;
        e.1 += 1;
        e.2 = e.2.max(seconds);
    }

    pub fn merge(&mut self, other: &ConvergenceLog) {
        for (&c, &(v, n, s)) in &other.acc {
            let e = self.acc.entry(c).or_insert((0.0, 0, 0.0));
            e.0 += v;
            e.1 += n;
            e.2 = e.2.max(s);
        }
    }

    /// Summed series (MF squared loss, LDA log-likelihood are sums over
    /// data partitions).
    pub fn summed(&self) -> Vec<Sample> {
        self.acc
            .iter()
            .map(|(&clock, &(v, _, s))| Sample {
                clock,
                seconds: s,
                value: v,
            })
            .collect()
    }

    /// Per-worker-mean series.
    pub fn mean(&self) -> Vec<Sample> {
        self.acc
            .iter()
            .map(|(&clock, &(v, n, s))| Sample {
                clock,
                seconds: s,
                value: v / n.max(1) as f64,
            })
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Final summed value (used for headline comparisons).
    pub fn last_value(&self) -> Option<f64> {
        self.summed().last().map(|s| s.value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_across_workers() {
        let mut log = ConvergenceLog::new();
        log.report(0, 1.0, 10.0);
        log.report(0, 1.5, 20.0);
        log.report(1, 2.0, 8.0);
        let s = log.summed();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].value, 30.0);
        assert_eq!(s[0].seconds, 1.5); // completion time = max
        assert_eq!(s[1].value, 8.0);
    }

    #[test]
    fn mean_divides_by_reports() {
        let mut log = ConvergenceLog::new();
        log.report(3, 0.0, 4.0);
        log.report(3, 0.0, 8.0);
        assert_eq!(log.mean()[0].value, 6.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = ConvergenceLog::new();
        a.report(0, 1.0, 1.0);
        let mut b = ConvergenceLog::new();
        b.report(0, 2.0, 2.0);
        b.report(1, 3.0, 3.0);
        a.merge(&b);
        assert_eq!(a.summed()[0].value, 3.0);
        assert_eq!(a.last_value(), Some(3.0));
    }
}
