//! Per-clock computation/communication breakdown — the Fig. 1 (right)
//! instrument.
//!
//! The client attributes wall time to `comm` whenever it is blocked waiting
//! on the network (pull replies, SSP wait condition, VAP value-bound
//! stalls) and to `comp` otherwise. The harness aggregates the per-clock
//! splits into the stacked-bar series the paper plots for LDA.

use std::time::Duration;

/// One clock tick's time split on one worker.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClockSplit {
    pub comp_ns: u64,
    pub comm_ns: u64,
}

/// Time-split series for one worker.
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    clocks: Vec<ClockSplit>,
    cur_comm_ns: u64,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add blocked time to the clock currently in progress.
    pub fn add_comm(&mut self, d: Duration) {
        self.cur_comm_ns += d.as_nanos() as u64;
    }

    /// Comm time accrued in the clock currently in progress. The harness
    /// uses this to straggle *compute* only — multiplying blocked time
    /// would create a positive feedback loop between workers.
    pub fn current_comm(&self) -> Duration {
        Duration::from_nanos(self.cur_comm_ns)
    }

    /// Close the current clock: `elapsed` is the total wall time of the
    /// tick; comp = elapsed - comm accumulated during it.
    pub fn finish_clock(&mut self, elapsed: Duration) {
        let total = elapsed.as_nanos() as u64;
        let comm = self.cur_comm_ns.min(total);
        self.clocks.push(ClockSplit {
            comp_ns: total - comm,
            comm_ns: comm,
        });
        self.cur_comm_ns = 0;
    }

    pub fn len(&self) -> usize {
        self.clocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clocks.is_empty()
    }

    pub fn splits(&self) -> &[ClockSplit] {
        &self.clocks
    }

    pub fn total_comp(&self) -> Duration {
        Duration::from_nanos(self.clocks.iter().map(|c| c.comp_ns).sum())
    }

    pub fn total_comm(&self) -> Duration {
        Duration::from_nanos(self.clocks.iter().map(|c| c.comm_ns).sum())
    }

    /// Fraction of wall time spent blocked on communication.
    pub fn comm_fraction(&self) -> f64 {
        let comp = self.total_comp().as_secs_f64();
        let comm = self.total_comm().as_secs_f64();
        if comp + comm == 0.0 {
            0.0
        } else {
            comm / (comp + comm)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_accounting() {
        let mut t = Timeline::new();
        t.add_comm(Duration::from_millis(30));
        t.finish_clock(Duration::from_millis(100));
        assert_eq!(t.len(), 1);
        let s = t.splits()[0];
        assert_eq!(s.comm_ns, 30_000_000);
        assert_eq!(s.comp_ns, 70_000_000);
        assert!((t.comm_fraction() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn comm_capped_at_elapsed() {
        let mut t = Timeline::new();
        t.add_comm(Duration::from_millis(120));
        t.finish_clock(Duration::from_millis(100));
        let s = t.splits()[0];
        assert_eq!(s.comm_ns, 100_000_000);
        assert_eq!(s.comp_ns, 0);
    }

    #[test]
    fn comm_resets_between_clocks() {
        let mut t = Timeline::new();
        t.add_comm(Duration::from_millis(10));
        t.finish_clock(Duration::from_millis(20));
        t.finish_clock(Duration::from_millis(20));
        assert_eq!(t.splits()[1].comm_ns, 0);
        assert_eq!(t.splits()[1].comp_ns, 20_000_000);
    }
}
