//! CSV/JSON export of run metrics into `results/` — every experiment
//! harness writes its series through here so figures regenerate from flat
//! files.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::convergence::Sample;
use super::staleness::StalenessHist;
use crate::util::json::{arr, num, obj, str as jstr, Json};

/// Write a CSV file with the given header and rows.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut f = fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Staleness histogram -> CSV (differential, count, fraction).
pub fn staleness_csv(path: &Path, label: &str, hist: &StalenessHist) -> Result<()> {
    let total = hist.total().max(1) as f64;
    let rows: Vec<Vec<String>> = hist
        .buckets()
        .map(|(d, c)| {
            vec![
                label.to_string(),
                d.to_string(),
                c.to_string(),
                format!("{:.6}", c as f64 / total),
            ]
        })
        .collect();
    write_csv(path, &["label", "differential", "count", "fraction"], &rows)
}

/// Convergence series -> CSV (label, clock, seconds, value).
pub fn convergence_csv(path: &Path, series: &[(String, Vec<Sample>)]) -> Result<()> {
    let mut rows = Vec::new();
    for (label, samples) in series {
        for s in samples {
            rows.push(vec![
                label.clone(),
                s.clock.to_string(),
                format!("{:.4}", s.seconds),
                format!("{:.6}", s.value),
            ]);
        }
    }
    write_csv(path, &["label", "clock", "seconds", "value"], &rows)
}

/// Arbitrary summary object -> pretty JSON file.
pub fn write_json(path: &Path, value: &Json) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    fs::write(path, value.to_string_pretty(1)).with_context(|| format!("write {}", path.display()))
}

/// Build a summary JSON for a staleness histogram.
pub fn staleness_summary(label: &str, hist: &StalenessHist) -> Json {
    obj(vec![
        ("label", jstr(label)),
        ("total_reads", num(hist.total() as f64)),
        ("mean", num(hist.mean())),
        ("variance", num(hist.variance())),
        ("min", num(hist.min().unwrap_or(0) as f64)),
        ("max", num(hist.max().unwrap_or(0) as f64)),
        (
            "normalized",
            arr(hist
                .normalized()
                .into_iter()
                .map(|(d, f)| arr(vec![num(d as f64), num(f)]))
                .collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("essptable-test-{}", std::process::id()));
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        )
        .unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn staleness_csv_fractions_sum() {
        let mut h = StalenessHist::new();
        h.record(-1);
        h.record(-1);
        h.record(0);
        let dir = std::env::temp_dir().join(format!("essptable-test2-{}", std::process::id()));
        let path = dir.join("s.csv");
        staleness_csv(&path, "essp", &h).unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert!(text.contains("essp,-1,2,0.666667"));
        fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn summary_json_shape() {
        let mut h = StalenessHist::new();
        h.record(-1);
        let j = staleness_summary("x", &h);
        assert_eq!(j.get("total_reads").unwrap().as_u64().unwrap(), 1);
        assert!(j.get("normalized").unwrap().as_arr().unwrap().len() == 1);
    }
}
