//! Staleness histogram — the Fig. 1 (left) instrument.
//!
//! On every GET the client records the *clock differential*:
//! `fresh - c_worker`, where `fresh` is the max update clock reflected in
//! the row copy it read and `c_worker` is the clock it is working on. Under
//! BSP this is identically -1 (you see everything up to the barrier and
//! nothing newer); under SSP it spreads toward -(s+1); under ESSP it
//! concentrates near 0 (and can be positive when faster workers' best-
//! effort updates are already reflected).

use std::collections::BTreeMap;

use crate::ps::types::Clock;

/// Integer-valued histogram over clock differentials.
#[derive(Debug, Default, Clone)]
pub struct StalenessHist {
    counts: BTreeMap<Clock, u64>,
    total: u64,
}

impl StalenessHist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, differential: Clock) {
        *self.counts.entry(differential).or_insert(0) += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn count(&self, differential: Clock) -> u64 {
        self.counts.get(&differential).copied().unwrap_or(0)
    }

    /// Merge another histogram (per-worker -> global aggregation).
    pub fn merge(&mut self, other: &StalenessHist) {
        for (&d, &c) in &other.counts {
            *self.counts.entry(d).or_insert(0) += c;
        }
        self.total += other.total;
    }

    /// Mean differential — the μ_γ analogue the theory section says drives
    /// the convergence-rate gap between ESSP and SSP (Theorem 5).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let s: f64 = self
            .counts
            .iter()
            .map(|(&d, &c)| d as f64 * c as f64)
            .sum();
        s / self.total as f64
    }

    /// Variance of the differential (σ_γ analogue).
    pub fn variance(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let m = self.mean();
        let s: f64 = self
            .counts
            .iter()
            .map(|(&d, &c)| (d as f64 - m).powi(2) * c as f64)
            .sum();
        s / self.total as f64
    }

    /// (differential, count) pairs in ascending differential order.
    pub fn buckets(&self) -> impl Iterator<Item = (Clock, u64)> + '_ {
        self.counts.iter().map(|(&d, &c)| (d, c))
    }

    /// Normalized (differential, fraction) series — Fig. 1's y-axis.
    pub fn normalized(&self) -> Vec<(Clock, f64)> {
        self.counts
            .iter()
            .map(|(&d, &c)| (d, c as f64 / self.total.max(1) as f64))
            .collect()
    }

    pub fn min(&self) -> Option<Clock> {
        self.counts.keys().next().copied()
    }

    pub fn max(&self) -> Option<Clock> {
        self.counts.keys().next_back().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_normalizes() {
        let mut h = StalenessHist::new();
        for _ in 0..3 {
            h.record(-1);
        }
        h.record(2);
        assert_eq!(h.total(), 4);
        assert_eq!(h.count(-1), 3);
        let n = h.normalized();
        assert_eq!(n, vec![(-1, 0.75), (2, 0.25)]);
    }

    #[test]
    fn mean_and_variance() {
        let mut h = StalenessHist::new();
        h.record(-2);
        h.record(0);
        assert!((h.mean() + 1.0).abs() < 1e-12);
        assert!((h.variance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = StalenessHist::new();
        a.record(-1);
        let mut b = StalenessHist::new();
        b.record(-1);
        b.record(3);
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.count(-1), 2);
        assert_eq!((a.min(), a.max()), (Some(-1), Some(3)));
    }

    #[test]
    fn empty_is_safe() {
        let h = StalenessHist::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.variance(), 0.0);
        assert_eq!(h.min(), None);
    }
}
