//! Offline drop-in subset of the `anyhow` error-handling API.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface `essptable` uses: `Error`, `Result<T>`,
//! the `anyhow!` / `bail!` / `ensure!` macros, and the `Context` extension
//! trait for `Result` and `Option`. Errors are message chains: wrapping
//! with context prepends `"{context}: "` segments, and the alternate
//! formatter (`{:#}`) prints the full chain, matching how the real crate
//! is used in this codebase.

use std::fmt::{self, Debug, Display};

/// A boxed, context-augmentable error message chain.
pub struct Error {
    /// Outermost context first; the root cause is the last element.
    chain: Vec<String>,
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message (the real crate's
    /// `Error::msg`).
    pub fn msg<M: Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context layer.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (without the cause chain).
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost first — "ctx: ctx: cause".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a Caused by list.
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?` (the real crate's blanket From). The
// source chain is flattened into the message chain.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_alternate_prints_all() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_compose() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Err(anyhow!("always fails with {}", x))
        }
        assert!(format!("{}", f(-1).unwrap_err()).contains("negative"));
        assert!(format!("{}", f(200).unwrap_err()).contains("too big"));
        assert!(format!("{}", f(5).unwrap_err()).contains("always fails with 5"));
    }
}
