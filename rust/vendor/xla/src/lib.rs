//! API-compatible stub of the XLA/PJRT bindings used by
//! `essptable::runtime::engine`.
//!
//! The build environment has no crates.io access and no PJRT shared
//! library, so this vendored crate mirrors the exact type/method surface
//! the engine compiles against. `PjRtClient::cpu()` — the entry point to
//! every execution path — returns an error, which the engine and the
//! integration tests already treat as "runtime unavailable, skip" (the
//! same behavior as a checkout without `make artifacts`). Swapping in the
//! real bindings is a one-line Cargo change; no engine code needs to
//! differ.

use std::fmt;

/// Error type: the engine only ever Display-formats these.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT runtime not available in this build (vendored stub)".to_string(),
    ))
}

/// Element types an engine literal can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for f64 {}

/// Scalar element type of an array shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum PrimitiveType {
    F32,
    F64,
    S32,
    S64,
    U32,
    Pred,
}

/// Dims + element type of an array.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

/// Shape of a literal: array or tuple.
#[derive(Debug, Clone)]
#[non_exhaustive]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// A host-side literal (tensor value). The stub records only the payload
/// size — no execution path can ever consume the data.
#[derive(Debug, Clone)]
pub struct Literal {
    len_bytes: usize,
    shape: Option<Shape>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            len_bytes: std::mem::size_of_val(data),
            shape: None,
        }
    }

    /// Reshape to `dims` (stub: carries the request through).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.shape {
            Some(s) => Ok(s.clone()),
            None => unavailable(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Payload size (stub introspection; unused by the engine).
    pub fn size_bytes(&self) -> usize {
        self.len_bytes
    }
}

/// Parsed HLO module (stub: retains nothing).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with one replica/partition; `[replica][output]` buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. The stub's `cpu()` always fails — callers treat
/// that as "runtime unavailable" and skip, matching a checkout without
/// the native PJRT library.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
    }

    #[test]
    fn vec1_roundtrips_byte_length() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.size_bytes(), 12);
        assert!(l.shape().is_err());
    }
}
