//! Wire-codec property tests (offline vendor set has no `proptest`, so
//! this uses the same seeded-case harness as `proptest_invariants`):
//!
//!   * `decode(encode(m)) == m` over randomly generated messages of every
//!     `ToShard`/`ToWorker` variant, with `wire_bytes()` checked against
//!     the actual encoded length on every case (one source of truth);
//!   * every proper prefix of a frame fails cleanly (no panic, no bogus
//!     decode) — the truncation fuzz;
//!   * garbage kind/node bytes, trailing bytes, and lying payload-length
//!     fields are rejected before any oversized allocation;
//!   * the wire-v7 hybrid push rows specifically: snapshot and
//!     delta-chain payloads roundtrip (special float bits included),
//!     garbage payload/repr tags and lying chain counts are rejected, and
//!     a lying base vclock decodes verbatim — certifying it is the
//!     client's job, not the codec's;
//!   * the wire-v9 span context: random sampled/unsampled spans ride the
//!     four data-plane variants through the same roundtrip + truncation
//!     fuzz, and `span: None` encodes byte-identical to a pre-v9 frame
//!     (the zero-byte-when-unsampled invariant bit-identity rests on).

use std::sync::Arc;

use essptable::ps::msg::{PushPayload, PushRow, ToShard, ToWorker};
use essptable::ps::placement::PlacementDelta;
use essptable::ps::types::{Key, RowDelta};
use essptable::telemetry::spans::{SpanCtx, SPAN_WIRE_BYTES};
use essptable::transport::wire;
use essptable::transport::{NodeId, Packet};
use essptable::util::rng::Rng;

const SRC: NodeId = NodeId::Worker(3);
const DST: NodeId = NodeId::Shard(1);

fn gen_key(rng: &mut Rng) -> Key {
    (rng.next_u32() % 64, rng.below(1 << 20))
}

fn gen_clock(rng: &mut Rng) -> i64 {
    // Mixed-sign clocks, including NEVER-ish negatives.
    (rng.next_u64() as i64) >> 16
}

fn gen_payload(rng: &mut Rng) -> Vec<f32> {
    let n = rng.usize_below(33);
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn gen_arc(rng: &mut Rng) -> Arc<[f32]> {
    gen_payload(rng).into()
}

/// A random hybrid update-row delta: dense, or canonical sparse (strictly
/// ascending in-range indices, nnz within the density threshold).
fn gen_delta(rng: &mut Rng) -> RowDelta {
    if rng.f64() < 0.5 {
        RowDelta::Dense(gen_payload(rng))
    } else {
        let len = 1 + rng.usize_below(64);
        let nnz = rng.usize_below(len / 3 + 1);
        let mut idx: Vec<u32> = (0..len as u32).collect();
        rng.shuffle(&mut idx);
        idx.truncate(nnz);
        idx.sort_unstable();
        let pairs = idx.into_iter().map(|i| (i, rng.normal_f32())).collect();
        RowDelta::sparse(len, pairs)
    }
}

/// Random wire-v7 hybrid push rows: full snapshots mixed with delta
/// chains (any base, zero or more dense/sparse deltas per chain).
fn gen_push_rows(rng: &mut Rng) -> Vec<PushRow> {
    (0..rng.usize_below(9))
        .map(|_| {
            let key = gen_key(rng);
            let fresh = gen_clock(rng);
            if rng.f64() < 0.5 {
                PushRow::snapshot(key, gen_arc(rng), fresh)
            } else {
                let deltas: Arc<[RowDelta]> =
                    (0..rng.usize_below(5)).map(|_| gen_delta(rng)).collect();
                PushRow::deltas(key, gen_clock(rng), deltas, fresh)
            }
        })
        .collect()
}

/// A random wire-v9 span context: absent half the time (the common,
/// unsampled case), arbitrary trace/parent bits otherwise.
fn gen_span(rng: &mut Rng) -> Option<SpanCtx> {
    (rng.f64() < 0.5).then(|| SpanCtx {
        trace_id: rng.next_u64(),
        parent: rng.next_u32(),
    })
}

const TO_SHARD_VARIANTS: usize = 16;

fn gen_to_shard(rng: &mut Rng, variant: usize) -> ToShard {
    match variant {
        0 => ToShard::Get {
            key: gen_key(rng),
            worker: rng.usize_below(64),
            min_vclock: gen_clock(rng),
            span: gen_span(rng),
        },
        1 => ToShard::Update {
            worker: rng.usize_below(64),
            clock: gen_clock(rng),
            rows: (0..rng.usize_below(9))
                .map(|_| (gen_key(rng), gen_delta(rng)))
                .collect(),
            span: gen_span(rng),
        },
        2 => ToShard::ClockTick {
            worker: rng.usize_below(64),
            clock: gen_clock(rng),
        },
        3 => ToShard::Register {
            key: gen_key(rng),
            worker: rng.usize_below(64),
        },
        4 => ToShard::PushAck {
            worker: rng.usize_below(64),
            vclock: gen_clock(rng),
        },
        5 => ToShard::VapAck {
            worker: rng.usize_below(64),
            seq: rng.next_u64(),
        },
        6 => ToShard::NormReport {
            worker: rng.usize_below(64),
            clock: gen_clock(rng),
            inf_norm: rng.normal_f32().abs(),
        },
        7 => ToShard::Detach {
            worker: rng.usize_below(64),
        },
        8 => ToShard::MigrateBegin {
            epoch: rng.next_u64(),
            at_clock: gen_clock(rng),
            outgoing: (0..rng.usize_below(6))
                .map(|_| (gen_key(rng), rng.next_u32() % 16))
                .collect(),
            incoming: (0..rng.usize_below(6)).map(|_| gen_key(rng)).collect(),
        },
        9 => ToShard::RowHandoff {
            epoch: rng.next_u64(),
            key: gen_key(rng),
            vclock: gen_clock(rng),
            fresh: gen_clock(rng),
            exists: rng.f64() < 0.5,
            data: gen_arc(rng),
            staged: (0..rng.usize_below(4))
                .map(|_| (gen_clock(rng), rng.usize_below(64), gen_delta(rng)))
                .collect(),
        },
        10 => ToShard::MigrateCommit {
            epoch: rng.next_u64(),
        },
        11 => ToShard::Promote {
            delta: PlacementDelta {
                epoch: rng.next_u64(),
                at_clock: gen_clock(rng),
                grow_active: (rng.f64() < 0.3).then(|| 1 + rng.next_u32() % 64),
                promote: (rng.f64() < 0.7)
                    .then(|| (rng.next_u32() % 16, 16 + rng.next_u32() % 16)),
                attach: (rng.f64() < 0.4)
                    .then(|| (rng.next_u32() % 16, 32 + rng.next_u32() % 16)),
                dead: (0..rng.usize_below(4)).map(|_| rng.next_u32() % 48).collect(),
                moves: (0..rng.usize_below(5))
                    .map(|_| (gen_key(rng), rng.next_u32() % 16))
                    .collect(),
            },
        },
        12 => ToShard::StatsPull {
            worker: rng.usize_below(64),
        },
        13 => ToShard::ReplicaSync {
            epoch: rng.next_u64(),
            at_clock: gen_clock(rng),
            target: rng.next_u32() % 48,
        },
        14 => ToShard::ReplicaCatchUp {
            epoch: rng.next_u64(),
            at_clock: gen_clock(rng),
            source: rng.next_u32() % 48,
            from_disk: rng.f64() < 0.5,
        },
        _ => ToShard::Shutdown,
    }
}

/// Random flattened stats entries — the `StatsReport` payload: plain
/// counter names, `#`-suffixed histogram-bucket names, names right at the
/// 256-byte decode bound, and the empty name (legal, if useless).
fn gen_stat_entries(rng: &mut Rng) -> Vec<(String, u64)> {
    (0..rng.usize_below(13))
        .map(|_| {
            let name = match rng.usize_below(8) {
                0 => String::new(),
                1 => "n".repeat(1 + rng.usize_below(256)),
                2 => format!("read_latency_ns#b{}", rng.usize_below(65)),
                3 => format!("wal_append_ns#{}", ["count", "sum"][rng.usize_below(2)]),
                _ => format!("gets_served_{}", rng.usize_below(100)),
            };
            (name, rng.next_u64())
        })
        .collect()
}

const TO_WORKER_VARIANTS: usize = 6;

fn gen_to_worker(rng: &mut Rng, variant: usize) -> ToWorker {
    match variant {
        0 => ToWorker::Row {
            key: gen_key(rng),
            data: gen_arc(rng),
            vclock: gen_clock(rng),
            fresh: gen_clock(rng),
            span: gen_span(rng),
        },
        1 => ToWorker::Push {
            shard: rng.usize_below(16),
            vclock: gen_clock(rng),
            rows: gen_push_rows(rng),
            span: gen_span(rng),
        },
        2 => ToWorker::VapPush {
            shard: rng.usize_below(16),
            seq: rng.next_u64(),
            rows: gen_push_rows(rng),
        },
        3 => ToWorker::Bound {
            shard: rng.usize_below(16),
            granted: rng.f64() < 0.5,
        },
        4 => ToWorker::Placement {
            delta: PlacementDelta {
                epoch: rng.next_u64(),
                at_clock: gen_clock(rng),
                grow_active: (rng.f64() < 0.5).then(|| 1 + rng.next_u32() % 64),
                promote: (rng.f64() < 0.3)
                    .then(|| (rng.next_u32() % 16, 16 + rng.next_u32() % 16)),
                attach: (rng.f64() < 0.3)
                    .then(|| (rng.next_u32() % 16, 32 + rng.next_u32() % 16)),
                dead: (0..rng.usize_below(4)).map(|_| rng.next_u32() % 48).collect(),
                moves: (0..rng.usize_below(5))
                    .map(|_| (gen_key(rng), rng.next_u32() % 16))
                    .collect(),
            },
        },
        _ => ToWorker::StatsReport {
            shard: rng.usize_below(16),
            entries: gen_stat_entries(rng),
        },
    }
}

fn encode(p: &Packet) -> Vec<u8> {
    let mut v = Vec::new();
    wire::write_frame(&mut v, SRC, DST, p).unwrap();
    v
}

fn roundtrip(p: Packet) {
    let bytes = encode(&p);
    assert_eq!(
        bytes.len(),
        p.wire_bytes(),
        "wire_bytes() is not the encoded size for {p:?}"
    );
    let mut r = &bytes[..];
    let mut scratch = Vec::new();
    let (src, dst, back) = wire::read_frame(&mut r, &mut scratch)
        .expect("decode failed")
        .expect("unexpected EOF");
    assert_eq!((src, dst), (SRC, DST));
    assert_eq!(back, p, "roundtrip mismatch");
    assert!(r.is_empty(), "decoder left bytes unconsumed");
    // The stream is exactly one frame: the next read is a clean EOF.
    assert!(wire::read_frame(&mut r, &mut scratch).unwrap().is_none());
}

#[test]
fn prop_roundtrip_every_to_shard_variant() {
    for case in 0..300 {
        let mut rng = Rng::with_stream(0x3317e, case);
        for v in 0..TO_SHARD_VARIANTS {
            roundtrip(Packet::ToShard(gen_to_shard(&mut rng, v)));
        }
    }
}

#[test]
fn prop_roundtrip_every_to_worker_variant() {
    for case in 0..300 {
        let mut rng = Rng::with_stream(0x3317f, case);
        for v in 0..TO_WORKER_VARIANTS {
            roundtrip(Packet::ToWorker(gen_to_worker(&mut rng, v)));
        }
    }
}

#[test]
fn prop_back_to_back_frames_stream_cleanly() {
    // Many frames concatenated on one stream (what a TCP reader sees)
    // decode in order with nothing lost or reordered.
    let mut rng = Rng::with_stream(0x57123a, 7);
    let msgs: Vec<Packet> = (0..50)
        .map(|i| {
            if i % 2 == 0 {
                Packet::ToShard(gen_to_shard(&mut rng, i % TO_SHARD_VARIANTS))
            } else {
                Packet::ToWorker(gen_to_worker(&mut rng, i % TO_WORKER_VARIANTS))
            }
        })
        .collect();
    let mut stream = Vec::new();
    for m in &msgs {
        wire::write_frame(&mut stream, SRC, DST, m).unwrap();
    }
    let mut r = &stream[..];
    let mut scratch = Vec::new();
    for expect in &msgs {
        let (_, _, got) = wire::read_frame(&mut r, &mut scratch).unwrap().unwrap();
        assert_eq!(&got, expect);
    }
    assert!(wire::read_frame(&mut r, &mut scratch).unwrap().is_none());
}

fn check_truncations(p: Packet) {
    let bytes = encode(&p);
    for cut in 0..bytes.len() {
        let mut r = &bytes[..cut];
        let mut scratch = Vec::new();
        match wire::read_frame(&mut r, &mut scratch) {
            Ok(None) => assert_eq!(cut, 0, "clean EOF mid-frame at {cut} bytes"),
            Ok(Some(m)) => panic!(
                "decoded {m:?} from a {cut}-byte prefix of a {}-byte frame",
                bytes.len()
            ),
            Err(_) => {} // the required outcome: a clean error
        }
    }
}

#[test]
fn prop_truncated_frames_error_cleanly_every_variant() {
    for case in 0..20 {
        let mut rng = Rng::with_stream(0x77aa, case);
        for v in 0..TO_SHARD_VARIANTS {
            check_truncations(Packet::ToShard(gen_to_shard(&mut rng, v)));
        }
        for v in 0..TO_WORKER_VARIANTS {
            check_truncations(Packet::ToWorker(gen_to_worker(&mut rng, v)));
        }
    }
}

#[test]
fn garbage_prefix_per_variant_is_rejected() {
    // Flip the kind byte (offset 14: len 4 + src 5 + dst 5) to an unknown
    // value for one encoded frame of every variant: decode must fail.
    let mut rng = Rng::with_stream(0x9b1d, 1);
    let mut frames: Vec<Vec<u8>> = Vec::new();
    for v in 0..TO_SHARD_VARIANTS {
        frames.push(encode(&Packet::ToShard(gen_to_shard(&mut rng, v))));
    }
    for v in 0..TO_WORKER_VARIANTS {
        frames.push(encode(&Packet::ToWorker(gen_to_worker(&mut rng, v))));
    }
    for bytes in &mut frames {
        bytes[14] = 0x7F;
        let mut r = &bytes[..];
        let err = wire::read_frame(&mut r, &mut Vec::new());
        assert!(err.is_err(), "unknown kind byte accepted");
        assert!(
            format!("{:#}", err.unwrap_err()).contains("unknown message kind"),
            "wrong error"
        );
    }
    // Garbage node kind in the src address.
    let mut bytes = encode(&Packet::ToShard(ToShard::Shutdown));
    bytes[4] = 9;
    assert!(wire::read_frame(&mut &bytes[..], &mut Vec::new()).is_err());
}

#[test]
fn trailing_bytes_inside_a_frame_are_rejected() {
    // Grow the declared frame length and append padding: the body parses
    // but leaves residue, which must be an error (catches length lies).
    let mut bytes = encode(&Packet::ToShard(ToShard::ClockTick { worker: 1, clock: 2 }));
    let len = u32::from_le_bytes(bytes[..4].try_into().unwrap());
    bytes[..4].copy_from_slice(&(len + 4).to_le_bytes());
    bytes.extend_from_slice(&[0u8; 4]);
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("trailing"), "{err:#}");
}

#[test]
fn lying_row_count_is_bounded_before_allocation() {
    // A Push frame whose row count claims 2^31 rows in a tiny body must
    // fail on the remaining-bytes bound, not attempt the allocation.
    // Layout after kind byte (offset 15): shard u32 | vclock i64 | n u32.
    let mut bytes = encode(&Packet::ToWorker(ToWorker::Push {
        shard: 0,
        vclock: 1,
        rows: vec![],
        span: None,
    }));
    let n_off = 15 + 4 + 8;
    bytes[n_off..n_off + 4].copy_from_slice(&(1u32 << 31).to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("claims"), "{err:#}");
}

/// Offset of a Push frame's first row, after the row count. Layout after
/// the kind byte (offset 15): shard u32 | vclock i64 | nrows u32 | rows.
/// Each wire-v7 row: key (u32+u64) | fresh i64 | payload tag u8 | body;
/// a delta-chain body: base i64 | m u32 | m keyless repr-tagged deltas.
const PUSH_ROW0: usize = 15 + 4 + 8 + 4;

fn encoded_delta_push(deltas: Vec<RowDelta>) -> Vec<u8> {
    encode(&Packet::ToWorker(ToWorker::Push {
        shard: 1,
        vclock: 5,
        rows: vec![PushRow::deltas((0, 0), 3, deltas.into(), 4)],
        span: None,
    }))
}

#[test]
fn garbage_push_payload_tag_is_rejected() {
    let mut bytes = encoded_delta_push(vec![RowDelta::Dense(vec![1.0])]);
    bytes[PUSH_ROW0 + 20] = 9;
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("bad payload tag"), "{err:#}");
}

#[test]
fn lying_delta_chain_count_is_bounded_before_allocation() {
    // A chain claiming 2^31 deltas in a tiny body must fail on the
    // remaining-bytes bound, never attempt the allocation.
    let mut bytes = encoded_delta_push(vec![]);
    let m_off = PUSH_ROW0 + 21 + 8;
    bytes[m_off..m_off + 4].copy_from_slice(&(1u32 << 31).to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("claims"), "{err:#}");
}

#[test]
fn garbage_delta_repr_byte_in_a_chain_is_rejected() {
    // Chain deltas reuse the update-row hybrid codec; a garbage repr tag
    // inside a chain is stream corruption like anywhere else.
    let mut bytes = encoded_delta_push(vec![RowDelta::Dense(vec![1.0])]);
    bytes[PUSH_ROW0 + 21 + 12] = 9;
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("representation"), "{err:#}");
}

#[test]
fn lying_base_vclock_is_decoded_verbatim_for_the_client_to_judge() {
    // The chain base is a claim, not a checksum: any i64 decodes cleanly
    // and arrives verbatim — certification (discard + re-pull on a cached
    // copy that is not exactly at `base`) is the client fold's job, so a
    // lying base must never corrupt the stream or kill the connection.
    let mut bytes = encoded_delta_push(vec![RowDelta::sparse(8, vec![(2, 1.5)])]);
    let base_off = PUSH_ROW0 + 21;
    bytes[base_off..base_off + 8].copy_from_slice(&(-12345i64).to_le_bytes());
    let (_, _, back) = wire::read_frame(&mut &bytes[..], &mut Vec::new())
        .unwrap()
        .unwrap();
    match back {
        Packet::ToWorker(ToWorker::Push { rows, .. }) => match &rows[0].payload {
            PushPayload::Deltas { base, deltas } => {
                assert_eq!(*base, -12345, "patched base must arrive verbatim");
                assert_eq!(deltas.len(), 1);
            }
            other => panic!("unexpected payload {other:?}"),
        },
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn delta_chain_special_float_bits_survive_roundtrip() {
    // NaN payloads, signed zero and denormals ride chain deltas
    // bit-exactly — the client fold replays the shard's exact arithmetic,
    // which only holds if the wire never normalizes a float.
    let specials = vec![
        f32::NAN,
        f32::from_bits(0x7FC0_1234), // payloaded NaN
        -0.0,
        f32::MIN_POSITIVE / 2.0, // denormal
        f32::NEG_INFINITY,
    ];
    let chain = vec![
        RowDelta::Dense(specials.clone()),
        RowDelta::sparse(specials.len(), vec![(0, f32::from_bits(0x8000_0001))]),
    ];
    let bytes = encoded_delta_push(chain.clone());
    let (_, _, back) = wire::read_frame(&mut &bytes[..], &mut Vec::new())
        .unwrap()
        .unwrap();
    match back {
        Packet::ToWorker(ToWorker::Push { rows, .. }) => match &rows[0].payload {
            PushPayload::Deltas { deltas, .. } => {
                assert_eq!(deltas.len(), 2);
                match (&deltas[0], &chain[0]) {
                    (RowDelta::Dense(got), RowDelta::Dense(sent)) => {
                        for (a, b) in sent.iter().zip(got) {
                            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost its bit pattern");
                        }
                    }
                    other => panic!("representation not preserved: {other:?}"),
                }
                match &deltas[1] {
                    RowDelta::Sparse { pairs, .. } => {
                        assert_eq!(pairs[0].1.to_bits(), 0x8000_0001);
                    }
                    other => panic!("representation not preserved: {other:?}"),
                }
            }
            other => panic!("unexpected payload {other:?}"),
        },
        other => panic!("unexpected {other:?}"),
    }
}

/// Offset of an Update frame's first row, after the row count. Layout
/// after the kind byte (offset 15): worker u32 | clock i64 | nrows u32 |
/// rows. Each row: key (u32+u64) | repr u8 | repr-specific body.
const UPDATE_ROW0: usize = 15 + 4 + 8 + 4;

#[test]
fn lying_payload_length_is_bounded_before_allocation() {
    // A dense Update row claiming u32::MAX f32s: rejected by the byte
    // bound. Dense body after the repr byte: len u32 | payload.
    let mut bytes = encode(&Packet::ToShard(ToShard::Update {
        worker: 0,
        clock: 1,
        rows: vec![((0, 0), vec![1.0, 2.0].into())],
        span: None,
    }));
    let len_off = UPDATE_ROW0 + 12 + 1;
    bytes[len_off..len_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("truncated") || msg.contains("overflow"),
        "{msg}"
    );
}

fn encoded_sparse_update() -> Vec<u8> {
    // One sparse row: len 8, pairs [(1, 1.0), (2, 2.0)]. Sparse body after
    // the repr byte: len u32 | nnz u32 | (idx u32, val f32)*.
    encode(&Packet::ToShard(ToShard::Update {
        worker: 0,
        clock: 1,
        rows: vec![((0, 0), RowDelta::sparse(8, vec![(1, 1.0), (2, 2.0)]))],
        span: None,
    }))
}

#[test]
fn lying_sparse_nnz_is_bounded_before_allocation() {
    // Claiming 2^31 pairs in a tiny body must fail on the remaining-bytes
    // bound, never attempt the allocation.
    let mut bytes = encoded_sparse_update();
    let nnz_off = UPDATE_ROW0 + 12 + 1 + 4;
    bytes[nnz_off..nnz_off + 4].copy_from_slice(&(1u32 << 31).to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("claims"), "{err:#}");
    // And an nnz that fits the bytes but exceeds the declared row length
    // is rejected too (here: len patched below nnz).
    let mut bytes = encoded_sparse_update();
    let len_off = UPDATE_ROW0 + 12 + 1;
    bytes[len_off..len_off + 4].copy_from_slice(&1u32.to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("claims"), "{err:#}");
}

#[test]
fn lying_sparse_row_len_is_bounded_before_any_allocation() {
    // `len` is a claim about the dense width the row expands to at apply
    // time: a tiny frame claiming a u32::MAX-wide row must be rejected at
    // decode, not allocate gigabytes in the shard later.
    let mut bytes = encoded_sparse_update();
    let len_off = UPDATE_ROW0 + 12 + 1;
    bytes[len_off..len_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("dense width"), "{err:#}");
}

#[test]
fn sparse_index_out_of_range_is_rejected() {
    let mut bytes = encoded_sparse_update();
    let idx0_off = UPDATE_ROW0 + 12 + 1 + 4 + 4;
    bytes[idx0_off..idx0_off + 4].copy_from_slice(&200u32.to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");
}

#[test]
fn sparse_index_order_violation_is_rejected() {
    // Second index patched to 0 (< first index 1): non-canonical pair
    // order is treated as stream corruption.
    let mut bytes = encoded_sparse_update();
    let idx1_off = UPDATE_ROW0 + 12 + 1 + 4 + 4 + 8;
    bytes[idx1_off..idx1_off + 4].copy_from_slice(&0u32.to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("ascending"), "{err:#}");
}

#[test]
fn garbage_row_representation_byte_is_rejected() {
    let mut bytes = encoded_sparse_update();
    bytes[UPDATE_ROW0 + 12] = 9;
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(
        format!("{err:#}").contains("representation"),
        "{err:#}"
    );
}

#[test]
fn sparse_special_float_bits_survive_roundtrip() {
    let specials = [
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0,
        f32::MIN_POSITIVE,
        f32::MAX,
        f32::from_bits(0x7FC0_1234), // payloaded NaN
    ];
    let pairs: Vec<(u32, f32)> = specials
        .iter()
        .enumerate()
        .map(|(i, &v)| (10 * i as u32, v))
        .collect();
    let p = Packet::ToShard(ToShard::Update {
        worker: 2,
        clock: 3,
        rows: vec![((1, 5), RowDelta::sparse(1024, pairs.clone()))],
        span: None,
    });
    let bytes = encode(&p);
    let (_, _, back) = wire::read_frame(&mut &bytes[..], &mut Vec::new())
        .unwrap()
        .unwrap();
    match back {
        Packet::ToShard(ToShard::Update { rows, .. }) => match &rows[0].1 {
            RowDelta::Sparse { len, pairs: got } => {
                assert_eq!(*len, 1024);
                assert_eq!(got.len(), pairs.len());
                for ((i, a), (j, b)) in pairs.iter().zip(got) {
                    assert_eq!(i, j);
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} lost its bit pattern");
                }
            }
            other => panic!("representation not preserved: {other:?}"),
        },
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn unsampled_spans_cost_zero_bytes_on_every_data_plane_variant() {
    // Wire v9's contract: `span: None` encodes byte-identical to a
    // pre-v9 frame, and a sampled span appends exactly SPAN_WIRE_BYTES
    // (`trace_id: u64 | parent: u32`, little-endian) at the very end of
    // the body. The spans-off bit-identity guarantee rests on the None
    // half; the trailing placement is what lets the offset-patching
    // tests in this file keep their hard-coded offsets.
    let ctx = SpanCtx {
        trace_id: 0x0123_4567_89AB_CDEF,
        parent: 0xA5A5_0F0F,
    };
    let variants: Vec<(&str, Packet, Packet)> = vec![
        (
            "Get",
            Packet::ToShard(ToShard::Get {
                key: (1, 2),
                worker: 3,
                min_vclock: 4,
                span: None,
            }),
            Packet::ToShard(ToShard::Get {
                key: (1, 2),
                worker: 3,
                min_vclock: 4,
                span: Some(ctx),
            }),
        ),
        (
            "Update",
            Packet::ToShard(ToShard::Update {
                worker: 0,
                clock: 1,
                rows: vec![((0, 0), vec![1.0, 2.0].into())],
                span: None,
            }),
            Packet::ToShard(ToShard::Update {
                worker: 0,
                clock: 1,
                rows: vec![((0, 0), vec![1.0, 2.0].into())],
                span: Some(ctx),
            }),
        ),
        (
            "Row",
            Packet::ToWorker(ToWorker::Row {
                key: (0, 0),
                data: vec![1.0f32].into(),
                vclock: 2,
                fresh: 1,
                span: None,
            }),
            Packet::ToWorker(ToWorker::Row {
                key: (0, 0),
                data: vec![1.0f32].into(),
                vclock: 2,
                fresh: 1,
                span: Some(ctx),
            }),
        ),
        (
            "Push",
            Packet::ToWorker(ToWorker::Push {
                shard: 0,
                vclock: 1,
                rows: vec![PushRow::snapshot((0, 0), vec![1.0f32].into(), 1)],
                span: None,
            }),
            Packet::ToWorker(ToWorker::Push {
                shard: 0,
                vclock: 1,
                rows: vec![PushRow::snapshot((0, 0), vec![1.0f32].into(), 1)],
                span: Some(ctx),
            }),
        ),
    ];
    for (tag, without, with) in variants {
        let a = encode(&without);
        let b = encode(&with);
        assert_eq!(b.len(), a.len() + SPAN_WIRE_BYTES, "{tag}");
        // Same bytes except the length prefix (first 4) and the span
        // tail: the sampled frame is the unsampled frame plus 12 bytes.
        assert_eq!(a[4..], b[4..b.len() - SPAN_WIRE_BYTES], "{tag}");
        let tail = &b[b.len() - SPAN_WIRE_BYTES..];
        assert_eq!(tail[..8], ctx.trace_id.to_le_bytes(), "{tag}");
        assert_eq!(tail[8..], ctx.parent.to_le_bytes(), "{tag}");
        roundtrip(without);
        roundtrip(with);
    }
}

#[test]
fn garbage_bound_bool_byte_is_rejected() {
    // Bound's granted flag is a strict 0/1 byte; anything else is treated
    // as stream corruption. Layout after kind byte (offset 15): shard u32
    // | granted u8.
    let mut bytes = encode(&Packet::ToWorker(ToWorker::Bound {
        shard: 2,
        granted: true,
    }));
    bytes[15 + 4] = 7;
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("bad bool"), "{err:#}");
}

#[test]
fn lying_stats_entry_count_is_bounded_before_allocation() {
    // StatsReport layout after the kind byte (offset 15): shard u32 |
    // n u32 | entries. A count claiming 2^31 entries in an empty body
    // must fail on the remaining-bytes bound, never touch the allocator.
    let mut bytes = encode(&Packet::ToWorker(ToWorker::StatsReport {
        shard: 0,
        entries: vec![],
    }));
    bytes[19..23].copy_from_slice(&(1u32 << 31).to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("claims"), "{err:#}");
}

#[test]
fn oversized_stat_name_is_rejected_at_the_length_bound() {
    // One entry with a 1-byte name; patch its name length (u16 at offset
    // 23) past MAX_STAT_NAME: the explicit bound rejects it first.
    let mut bytes = encode(&Packet::ToWorker(ToWorker::StatsReport {
        shard: 1,
        entries: vec![("x".to_string(), 7)],
    }));
    bytes[23..25].copy_from_slice(&300u16.to_le_bytes());
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("name of 300 bytes"), "{err:#}");
}

#[test]
fn non_utf8_stat_name_is_rejected_with_the_entry_index() {
    // Corrupt the single name byte (offset 25) into an invalid UTF-8
    // lead: the error names which entry was bad.
    let mut bytes = encode(&Packet::ToWorker(ToWorker::StatsReport {
        shard: 1,
        entries: vec![("x".to_string(), 7)],
    }));
    bytes[25] = 0xFF;
    let err = wire::read_frame(&mut &bytes[..], &mut Vec::new()).unwrap_err();
    assert!(format!("{err:#}").contains("stats entry 0 name"), "{err:#}");
}

// ----------------------------------------------- on-disk WAL format fuzz
//
// The shard WAL is a 22-byte header plus a stream of the same wire
// frames fuzzed above, so the defensive-decode guarantees extend to the
// durable plane: random truncation recovers a whole-frame prefix with
// the dropped tail reported, and arbitrary garbage never panics or
// provokes an attacker-sized allocation.

use essptable::ps::durability::wal::{self, WalWriter, WAL_HEADER_LEN};
use essptable::ps::durability::FsyncPolicy;
use std::path::PathBuf;

fn wal_tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esspt-walprop-{}-{tag}", std::process::id()))
}

fn write_wal(path: &PathBuf, records: &[ToShard]) {
    let mut w = WalWriter::create(path, 1, 3, FsyncPolicy::Off).unwrap();
    for m in records {
        w.append(m).unwrap();
    }
    w.commit().unwrap();
}

#[test]
fn prop_wal_roundtrips_random_records_of_every_variant() {
    let path = wal_tmp("roundtrip.wal");
    for case in 0..40 {
        let mut rng = Rng::with_stream(0x4a11, case);
        let records: Vec<ToShard> = (0..TO_SHARD_VARIANTS)
            .map(|v| gen_to_shard(&mut rng, v))
            .collect();
        write_wal(&path, &records);
        let read = wal::replay_strict(&path).expect("clean log must replay strictly");
        assert_eq!(read.records, records, "case {case}");
        assert_eq!(read.dropped_bytes, 0);
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn prop_wal_truncation_recovers_a_whole_frame_prefix() {
    // Chop a valid log at every byte: lenient replay must never panic,
    // must recover an exact prefix of the appended records, and must
    // account for every dropped byte. Cuts inside the header are errors
    // (there is no log to speak of), never panics.
    let path = wal_tmp("trunc.wal");
    let mut rng = Rng::with_stream(0x4a12, 9);
    let records: Vec<ToShard> = (0..TO_SHARD_VARIANTS)
        .map(|v| gen_to_shard(&mut rng, v))
        .collect();
    write_wal(&path, &records);
    let full = std::fs::read(&path).unwrap();
    for cut in 0..full.len() {
        std::fs::write(&path, &full[..cut]).unwrap();
        if cut < WAL_HEADER_LEN {
            assert!(wal::replay(&path).is_err(), "cut {cut}: headerless log accepted");
            continue;
        }
        let read = wal::replay(&path)
            .unwrap_or_else(|e| panic!("cut {cut}: lenient replay errored: {e:#}"));
        assert!(
            read.records.len() <= records.len(),
            "cut {cut}: more records than were written"
        );
        assert_eq!(
            read.records,
            records[..read.records.len()],
            "cut {cut}: recovered records are not a prefix"
        );
        assert!(
            read.dropped_bytes as usize <= cut.saturating_sub(WAL_HEADER_LEN),
            "cut {cut}: dropped more bytes than the body holds"
        );
        if read.dropped_bytes == 0 {
            // A clean cut must sit exactly at a frame boundary: strict
            // replay agrees.
            assert_eq!(
                wal::replay_strict(&path).unwrap().records.len(),
                read.records.len()
            );
        } else {
            assert!(wal::replay_strict(&path).is_err(), "cut {cut}: strict accepted a torn tail");
        }
    }
    std::fs::write(&path, &full).unwrap();
    assert_eq!(wal::replay(&path).unwrap().records, records);
    std::fs::remove_file(path).ok();
}

#[test]
fn prop_wal_single_bitflips_never_panic() {
    // Flip one random byte anywhere in a valid log: replay must return
    // (records or a context-rich error) without panicking, and whatever
    // it recovers must decode through the same bounded-allocation path.
    let path = wal_tmp("flip.wal");
    let mut rng = Rng::with_stream(0x4a13, 2);
    let records: Vec<ToShard> = (0..TO_SHARD_VARIANTS)
        .map(|v| gen_to_shard(&mut rng, v))
        .collect();
    write_wal(&path, &records);
    let full = std::fs::read(&path).unwrap();
    for case in 0..400u64 {
        let mut rng = Rng::with_stream(0x4a14, case);
        let mut bytes = full.clone();
        let at = rng.usize_below(bytes.len());
        bytes[at] ^= 1 << rng.usize_below(8);
        std::fs::write(&path, &bytes).unwrap();
        if let Ok(read) = wal::replay(&path) {
            assert!(
                read.records.len() <= records.len(),
                "case {case}: bitflip at {at} conjured extra records"
            );
        }
        // Err is equally acceptable; the property is "no panic, bounded".
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn prop_wal_random_garbage_never_panics() {
    // Pure noise, with and without a valid header prefix: the reader
    // must reject or truncate without panicking on any of it.
    let path = wal_tmp("noise.wal");
    let mut header = Vec::new();
    header.extend_from_slice(b"ESSPWAL1");
    header.extend_from_slice(&1u16.to_le_bytes());
    header.extend_from_slice(&0u32.to_le_bytes());
    header.extend_from_slice(&0u64.to_le_bytes());
    for case in 0..200u64 {
        let mut rng = Rng::with_stream(0x4a15, case);
        let n = rng.usize_below(256);
        let mut bytes: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8).collect();
        if case % 2 == 0 {
            // Half the cases get a well-formed header so the fuzz reaches
            // the frame decoder instead of dying at the magic check.
            let mut with_header = header.clone();
            with_header.append(&mut bytes);
            bytes = with_header;
        }
        std::fs::write(&path, &bytes).unwrap();
        let _ = wal::replay(&path); // Ok or Err, never a panic
        let _ = wal::replay_strict(&path);
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn special_float_bit_patterns_survive_roundtrip() {
    let specials = vec![
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        -0.0,
        f32::MIN_POSITIVE,
        f32::MAX,
        f32::from_bits(0x7FC0_1234), // payloaded NaN
    ];
    let p = Packet::ToWorker(ToWorker::Row {
        key: (0, 0),
        data: specials.clone().into(),
        vclock: 0,
        fresh: 0,
        span: None,
    });
    let bytes = encode(&p);
    let (_, _, back) = wire::read_frame(&mut &bytes[..], &mut Vec::new())
        .unwrap()
        .unwrap();
    match back {
        Packet::ToWorker(ToWorker::Row { data, .. }) => {
            assert_eq!(data.len(), specials.len());
            for (a, b) in specials.iter().zip(data.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} lost its bit pattern");
            }
        }
        other => panic!("unexpected {other:?}"),
    }
}

// ------------------------------------------ histogram snapshot properties
//
// `StatsReport` ships flattened `HistSnapshot`s and the admin plane merges
// them across nodes; these properties pin down what consumers may assume:
// the bucket bounds bracket the true rank-order statistic of the recorded
// stream, and bucket-wise merge is order-insensitive, so per-node
// snapshots fold into one global histogram in any order.

use essptable::telemetry::registry::{HistSnapshot, LogHist, Snapshot};

/// Mixed-magnitude samples: full-width draws shifted down by a random
/// amount so every bucket band gets traffic, capped below 2^55 so a few
/// hundred of them cannot overflow the running sum.
fn gen_samples(rng: &mut Rng) -> Vec<u64> {
    let n = 1 + rng.usize_below(200);
    (0..n)
        .map(|_| rng.next_u64() >> (9 + rng.usize_below(55)))
        .collect()
}

fn hist_of(samples: &[u64]) -> HistSnapshot {
    let mut h = HistSnapshot::default();
    for &v in samples {
        h.record(v);
    }
    h
}

#[test]
fn prop_hist_quantile_bounds_bracket_the_true_quantile() {
    for case in 0..200 {
        let mut rng = Rng::with_stream(0xb1a5, case);
        let samples = gen_samples(&mut rng);
        let h = hist_of(&samples);
        let mut sorted = samples;
        sorted.sort_unstable();
        for &q in &[0.0, 0.5, 0.9, 0.99, 0.999, 1.0] {
            // Same rank convention as quantile_bounds: ceil(q*n), 1-based.
            let rank = ((q * sorted.len() as f64).ceil() as u64).clamp(1, sorted.len() as u64);
            let truth = sorted[(rank - 1) as usize];
            let (lo, hi) = h.quantile_bounds(q);
            assert!(
                lo <= truth && truth <= hi,
                "case {case} q={q}: true quantile {truth} outside [{lo}, {hi}]"
            );
            assert_eq!(h.quantile(q), hi, "quantile() is the upper bound");
        }
    }
}

#[test]
fn hist_extremes_land_in_the_terminal_buckets() {
    // 0 and u64::MAX occupy the closed end buckets and the bounds still
    // bracket them (the sum stays exactly u64::MAX: no overflow).
    let h = hist_of(&[0, u64::MAX]);
    assert_eq!(h.quantile_bounds(0.0), (0, 0));
    assert_eq!(h.quantile_bounds(1.0), (1u64 << 63, u64::MAX));
    assert_eq!(h.sum, u64::MAX);
}

#[test]
fn prop_hist_merge_is_associative_and_commutative() {
    for case in 0..100 {
        let mut rng = Rng::with_stream(0xb1a6, case);
        let a = hist_of(&gen_samples(&mut rng));
        let b = hist_of(&gen_samples(&mut rng));
        let c = hist_of(&gen_samples(&mut rng));
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "case {case}: merge is not commutative");
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "case {case}: merge is not associative");
        assert_eq!(ab_c.count, a.count + b.count + c.count);
        assert_eq!(ab_c.sum, a.sum + b.sum + c.sum);
    }
}

#[test]
fn prop_atomic_and_plain_recording_agree() {
    // The lock-free LogHist a node records into and the plain snapshot a
    // report reassembles must describe the same distribution.
    for case in 0..50 {
        let mut rng = Rng::with_stream(0xb1a7, case);
        let samples = gen_samples(&mut rng);
        let atomic = LogHist::new();
        for &v in &samples {
            atomic.record(v);
        }
        assert_eq!(atomic.snapshot(), hist_of(&samples), "case {case}");
    }
}

#[test]
fn prop_hist_survives_flatten_and_wire_reassembly() {
    // entries() -> StatsReport wire roundtrip -> Snapshot::hist() is
    // lossless: what the worker-side mirror of shard reports relies on.
    for case in 0..50 {
        let mut rng = Rng::with_stream(0xb1a8, case);
        let h = hist_of(&gen_samples(&mut rng));
        let mut entries = Vec::new();
        h.entries("read_latency_ns", &mut entries);
        let p = Packet::ToWorker(ToWorker::StatsReport { shard: 2, entries });
        let bytes = encode(&p);
        let (_, _, back) = wire::read_frame(&mut &bytes[..], &mut Vec::new())
            .unwrap()
            .unwrap();
        let Packet::ToWorker(ToWorker::StatsReport { entries, .. }) = back else {
            panic!("unexpected {back:?}");
        };
        let snap = Snapshot {
            node: "shard2".to_string(),
            entries,
        };
        assert_eq!(snap.hist_names(), ["read_latency_ns"]);
        assert_eq!(snap.hist("read_latency_ns"), h, "case {case}");
    }
}
