//! Steady-state allocation audit of the ESSP eager wave path.
//!
//! The push/serve hot loop is built around reusable scratch (the shard's
//! wave scratch, the TCP writer's batch buffer, the SimNet intake
//! buffer), so a steady-state wave should cost a *fixed, small* number of
//! allocations — message envelopes and channel nodes only — with nothing
//! proportional to the row width or the wave index. A counting
//! `#[global_allocator]` (thread-local, so the router thread's work
//! doesn't alias the measurement) pins that down two ways:
//!
//!   * flat: after warmup, every wave performs exactly the same number of
//!     allocations — no per-wave growth, no leak-shaped drift;
//!   * width-independent: a K=1024 row costs the same allocation *count*
//!     as a K=16 row. Element-wise staging that grows a Vec by pushes
//!     would realloc ~log K times and break this equality.
//!
//! A count cap can't see a single exact-size staging copy, but the
//! zero-copy decode and install paths are covered by their own unit
//! tests; this test is the regression tripwire for the wave loop's
//! envelope costs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use essptable::ps::consistency::Consistency;
use essptable::ps::msg::{ToShard, ToWorker};
use essptable::ps::shard::Shard;
use essptable::ps::types::{Clock, RowDelta};
use essptable::sim::net::{NetConfig, SimNet};
use essptable::transport::TransportHandle;

struct CountingAlloc;

thread_local! {
    /// Allocations made by *this* thread (alloc + realloc events).
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // try_with: TLS may be mid-teardown on exiting threads.
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn my_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Spin-receive (no parking: `recv_timeout`'s park path may allocate and
/// muddy a later measurement window).
fn recv(rx: &Receiver<ToWorker>) -> ToWorker {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if let Ok(m) = rx.try_recv() {
            return m;
        }
        assert!(Instant::now() < deadline, "wave never arrived");
        std::thread::yield_now();
    }
}

/// Drive an ESSP shard directly on this thread: worker 0 commits one
/// sparse update per clock against a `row_len`-wide row, all `WORKERS`
/// tick, and each wave's eager pushes are drained. Returns the number of
/// this-thread allocations observed inside each wave's handle window
/// (update + ticks, where `push_wave` runs).
fn wave_allocs(row_len: usize, waves: usize) -> Vec<u64> {
    const WORKERS: usize = 5;
    let mut wtxs = Vec::new();
    let mut wrxs = Vec::new();
    for _ in 0..WORKERS {
        let (wtx, wrx) = channel();
        wtxs.push(wtx);
        wrxs.push(wrx);
    }
    let (stx, _srx) = channel();
    let net = SimNet::new(NetConfig::instant(), wtxs, vec![stx]);
    let mut shard = Shard::new(
        0,
        WORKERS,
        Consistency::Essp { s: 1 },
        TransportHandle::new(net.handle()),
        HashMap::new(),
        false,
    );
    shard.init_row((0, 1), vec![0.0; row_len]);
    for w in 0..WORKERS {
        shard.handle(ToShard::Register { key: (0, 1), worker: w });
    }
    let mut counts = Vec::new();
    for clock in 0..waves as Clock {
        let before = my_allocs();
        shard.handle(ToShard::Update {
            worker: 0,
            clock,
            rows: vec![((0, 1), RowDelta::sparse(row_len, vec![(0, 1.0), (3, 0.5)]))],
            span: None,
        });
        for w in 0..WORKERS {
            shard.handle(ToShard::ClockTick { worker: w, clock });
        }
        counts.push(my_allocs() - before);
        for wrx in &wrxs {
            let msg = recv(wrx);
            assert!(matches!(msg, ToWorker::Push { .. }), "unexpected {msg:?}");
        }
    }
    counts
}

#[test]
fn essp_wave_loop_allocations_are_flat_and_width_independent() {
    const WAVES: usize = 12;
    const WARMUP: usize = 4;
    // mpsc channels allocate a fresh block every ~31 sends, so a steady
    // wave occasionally costs a couple of extra envelope allocations —
    // the flatness bound is min..=min+SLACK, not strict equality.
    const SLACK: u64 = 3;
    let narrow = wave_allocs(16, WAVES);
    let wide = wave_allocs(1024, WAVES);
    let floor = |counts: &[u64]| *counts[WARMUP..].iter().min().unwrap();
    let narrow_floor = floor(&narrow);
    let wide_floor = floor(&wide);
    assert!(
        narrow[WARMUP..].iter().all(|&c| c <= narrow_floor + SLACK),
        "narrow-row wave allocations drift after warmup: {narrow:?}"
    );
    assert!(
        wide[WARMUP..].iter().all(|&c| c <= wide_floor + SLACK),
        "wide-row wave allocations drift after warmup: {wide:?}"
    );
    assert_eq!(
        narrow_floor, wide_floor,
        "allocation count depends on row width (narrow {narrow:?} vs wide {wide:?})"
    );
    // Envelope budget: one update + one wave to 5 readers should cost a
    // few dozen allocations (message vecs, channel nodes, the chain Arc,
    // the copy-on-write detach) — far under this cap. O(row)- or
    // O(readers^2)-shaped regressions blow straight through it.
    assert!(
        narrow_floor <= 96,
        "eager wave path allocates too much per wave: {narrow_floor}"
    );
}
