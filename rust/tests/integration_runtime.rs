//! Runtime integration: AOT artifacts -> PJRT -> numerics, and the XLA
//! compute path wired through the PS. Requires `make artifacts` to have
//! run (skips with a message otherwise, so `cargo test` stays green on a
//! fresh checkout before artifacts are built).

use std::sync::Once;

use essptable::apps::mf::native;
use essptable::apps::mf::train::{final_sq_loss, run_mf, MfBackend, MF_ARTIFACT};
use essptable::apps::mf::MfConfig;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::ClusterConfig;
use essptable::runtime::artifact::ArtifactDir;
use essptable::runtime::engine::{RuntimeService, Tensor};
use essptable::util::rng::Rng;

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::open(ArtifactDir::default_dir()) {
        Ok(d) => Some(d),
        Err(_) => {
            eprintln!("skipping runtime integration test: run `make artifacts` first");
            None
        }
    }
}

/// One shared runtime service across tests (PJRT client startup is slow).
fn runtime() -> Option<&'static RuntimeService> {
    static INIT: Once = Once::new();
    static mut SERVICE: Option<RuntimeService> = None;
    let mut ok = false;
    unsafe {
        INIT.call_once(|| {
            if let Some(dir) = artifacts() {
                if let Ok(svc) = RuntimeService::start(dir) {
                    SERVICE = Some(svc);
                }
            }
        });
        #[allow(static_mut_refs)]
        {
            ok = SERVICE.is_some();
            if ok {
                return SERVICE.as_ref();
            }
        }
    }
    let _ = ok;
    None
}

fn randv(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| s * rng.normal_f32()).collect()
}

#[test]
fn mf_artifact_matches_native_reference() {
    let Some(rt) = runtime() else { return };
    let handle = rt.handle();
    handle.preload(MF_ARTIFACT).expect("compile mf artifact");
    let mut rng = Rng::new(17);
    for case in 0..3 {
        let (bm, bn, k) = (64, 64, 32);
        let l = randv(&mut rng, bm * k, 0.5);
        let r = randv(&mut rng, k * bn, 0.5);
        let d = randv(&mut rng, bm * bn, 1.0);
        let mask: Vec<f32> = (0..bm * bn).map(|_| (rng.f64() < 0.3) as u8 as f32).collect();
        let (gamma, lambda) = (0.05f32, 0.02f32);
        let out = handle
            .execute(
                MF_ARTIFACT,
                vec![
                    Tensor::f32(vec![bm, k], l.clone()),
                    Tensor::f32(vec![k, bn], r.clone()),
                    Tensor::f32(vec![bm, bn], d.clone()),
                    Tensor::f32(vec![bm, bn], mask.clone()),
                    Tensor::f32(vec![2], vec![gamma, lambda]),
                ],
            )
            .expect("execute mf artifact");
        let dl_xla = out[0].as_f32().unwrap();
        let dr_xla = out[1].as_f32().unwrap();
        let stats = out[2].as_f32().unwrap();
        let (dl, dr, loss, cnt) =
            native::block_grads(&l, &r, &d, &mask, bm, bn, k, gamma, lambda);
        for (i, (a, b)) in dl_xla.iter().zip(&dl).enumerate() {
            assert!((a - b).abs() < 2e-4 * (1.0 + b.abs()), "case {case} dL[{i}]: {a} vs {b}");
        }
        for (i, (a, b)) in dr_xla.iter().zip(&dr).enumerate() {
            assert!((a - b).abs() < 2e-4 * (1.0 + b.abs()), "case {case} dR[{i}]: {a} vs {b}");
        }
        assert!((stats[0] - loss).abs() < 1e-2 * (1.0 + loss.abs()), "loss");
        assert_eq!(stats[1], cnt, "count");
    }
}

#[test]
fn mf_training_via_xla_backend_converges() {
    let Some(rt) = runtime() else { return };
    let handle = rt.handle();
    handle.preload(MF_ARTIFACT).expect("compile mf artifact");
    let mf = MfConfig {
        rows: 128,
        cols: 128,
        rank: 32, // artifact K
        block: 64,
        true_rank: 4,
        nnz_per_row: 24,
        noise: 0.01,
        gamma: 0.04,
        lambda: 0.01,
        minibatch: 1.0,
        ..Default::default()
    };
    let ccfg = ClusterConfig {
        workers: 2,
        shards: 1,
        consistency: Consistency::Essp { s: 1 },
        ..Default::default()
    };
    let (report, data) = run_mf(ccfg, mf, 15, MfBackend::Xla(handle));
    let series = report.convergence.summed();
    let first = series.first().unwrap().value;
    let last = series.last().unwrap().value;
    assert!(
        last < 0.6 * first,
        "XLA-backed MF did not converge: {first} -> {last}"
    );
    let f = final_sq_loss(&report, &data);
    assert!(f.is_finite() && f < first);
}

#[test]
fn lm_artifact_executes_and_improves() {
    let Some(rt) = runtime() else { return };
    let dir = artifacts().unwrap();
    let Ok(meta) = dir.meta("lm_step_gpt-tiny") else {
        eprintln!("skipping: lm_step_gpt-tiny not lowered");
        return;
    };
    let meta = meta.clone();
    let handle = rt.handle();
    let cfg = essptable::apps::lm::LmTrainConfig {
        artifact: "lm_step_gpt-tiny".into(),
        lr: 0.2,
        lr_decay: 100.0,
        seed: 3,
        branch: 4,
    };
    let ccfg = ClusterConfig {
        workers: 1,
        shards: 1,
        consistency: Consistency::Bsp,
        ..Default::default()
    };
    let report = essptable::apps::lm::run_lm(ccfg, cfg, &meta, handle, 4).expect("lm run");
    let series = report.convergence.mean();
    assert_eq!(series.len(), 4);
    let first = series.first().unwrap().value;
    let last = series.last().unwrap().value;
    // ln(vocab) at init; must be finite and non-increasing-ish in 4 steps.
    assert!(first.is_finite() && first > 6.0 && first < 10.0, "init loss {first}");
    assert!(last <= first + 0.05, "loss rose: {first} -> {last}");
}

#[test]
fn artifact_input_validation_rejects_bad_shapes() {
    let Some(rt) = runtime() else { return };
    let handle = rt.handle();
    let err = handle
        .execute(
            MF_ARTIFACT,
            vec![Tensor::f32(vec![2, 2], vec![0.0; 4])],
        )
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("expected 5 inputs"), "{msg}");
}
