//! Causal request-span + profiling-plane integration (wire v9).
//!
//!   * the whole profiling plane — sampled spans, the hot-key sketch,
//!     staleness-lag recording — is strictly out-of-band: deterministic
//!     runs are bit-identical with it at full blast vs off, for all six
//!     consistency models over both transports;
//!   * one shared `SpanRing` links client- and shard-side hops of the
//!     same sampled request by trace id, and the run report folds the
//!     segments into per-segment histograms plus a staleness-lag
//!     histogram;
//!   * the space-saving hot-key sketch ranks a Zipfian-skewed update
//!     stream correctly in the harvested shard registry;
//!   * a real multi-process `run-cluster --trace-spans` leaves ONE
//!     merged Chrome trace file in which the same trace id appears
//!     under distinct pids (worker and shard processes), and the admin
//!     socket serves the hot-key sketch mid-run.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::telemetry::admin::scrape;
use essptable::telemetry::spans::SpanRing;
use essptable::transport::TransportSel;
use essptable::util::json::Json;

fn assert_bit_identical(ctx: &str, a: &HashMap<Key, Vec<f32>>, b: &HashMap<Key, Vec<f32>>) {
    assert_eq!(a.len(), b.len(), "{ctx}: row sets differ");
    for (k, va) in a {
        let vb = b
            .get(k)
            .unwrap_or_else(|| panic!("{ctx}: row {k:?} missing"));
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: row {k:?} elem {i} differs: {x} vs {y}"
            );
        }
    }
}

// ------------------------------------------------------ out-of-band proof

/// The order-sensitive fractional counter, with the profiling plane
/// either fully off or at its most invasive setting: every eligible
/// frame sampled (`span_sample: 1`), hot-key sketches armed.
fn counter_run(
    transport: TransportSel,
    consistency: Consistency,
    probes: bool,
) -> HashMap<Key, Vec<f32>> {
    let workers = 3;
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: 2,
        consistency,
        transport,
        deterministic: true,
        spans: probes.then(|| Arc::new(SpanRing::new(8192))),
        span_sample: if probes { 1 } else { 0 },
        hot_key_k: if probes { 8 } else { 0 },
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[0.1 * (w + 1) as f32]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, 6).table_rows
}

#[test]
fn profiling_plane_at_full_blast_is_bit_identical_to_off() {
    // The tentpole's out-of-band claim: sample-every-frame spans plus
    // hot-key sketching must not perturb one bit of the deterministic
    // result, for every model class over both planes. Sampling is a
    // deterministic per-node counter and the 12-byte span context is
    // never a protocol input, so this holds exactly.
    let models = [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Async { refresh_every: 2 },
        Consistency::Vap { v0: 100.0 },
        Consistency::Avap { v0: 100.0, s: 2 },
    ];
    for consistency in models {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!("{} over {}", consistency.label(), transport.label());
            let plain = counter_run(transport, consistency, false);
            let probed = counter_run(transport, consistency, true);
            assert_bit_identical(&label, &plain, &probed);
        }
    }
}

// --------------------------------------------- causal linkage + RunReport

#[test]
fn span_ring_links_client_and_shard_hops_of_one_request() {
    let ring = Arc::new(SpanRing::new(65536));
    let workers = 3;
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: 2,
        consistency: Consistency::Essp { s: 1 },
        transport: TransportSel::Sim,
        deterministic: true,
        spans: Some(ring.clone()),
        span_sample: 1,
        hot_key_k: 4,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|_| {
            Box::new(|ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[1.0]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 8);

    // The report folds the ring into per-segment histograms: the
    // client-side issue segment and at least one shard-side segment
    // must be present, every histogram non-empty and well-formed.
    let seg = |name: &str| {
        report
            .span_segments
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, h)| h)
    };
    let issue = seg("client_issue").expect("client_issue segment missing");
    assert!(issue.count > 0, "client_issue histogram empty");
    assert!(
        seg("serve").is_some() || seg("apply").is_some(),
        "no shard-side segment in {:?}",
        report
            .span_segments
            .iter()
            .map(|(n, _)| n.as_str())
            .collect::<Vec<_>>()
    );
    for (name, h) in &report.span_segments {
        assert!(h.count > 0, "segment {name} has an empty histogram");
        assert!(h.quantile(0.50) <= h.quantile(0.99), "segment {name} malformed");
    }

    // Causal linkage: some sampled trace id was recorded by BOTH a
    // worker node and a shard node — the cross-node timeline the plane
    // exists for.
    let mut sides: HashMap<u64, HashSet<&'static str>> = HashMap::new();
    for ev in ring.events() {
        let side = if ev.node.starts_with("worker") {
            "worker"
        } else if ev.node.starts_with("shard") {
            "shard"
        } else {
            continue;
        };
        sides.entry(ev.trace_id).or_default().insert(side);
    }
    assert!(
        sides.values().any(|s| s.len() == 2),
        "no trace id crossed a node boundary ({} traces)",
        sides.len()
    );

    // The client-side staleness-lag histogram recorded every admitted
    // read (clamped lag, so BSP-tight models still count at bucket 0).
    assert!(report.staleness_lag.count > 0, "no staleness lags recorded");
}

// ------------------------------------------------------- hot-key ranking

#[test]
fn hot_key_sketch_ranks_a_zipfian_skew_in_the_harvested_registry() {
    // Every worker updates row 0 every clock and one of rows 1..=7 once
    // per 7 clocks — a crude Zipf head. The shard's space-saving sketch
    // must rank row 0 first, by a wide margin, in the harvested
    // registry entries (`hot.u.<table>:<row>`).
    let workers = 2;
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: 1,
        consistency: Consistency::Essp { s: 1 },
        transport: TransportSel::Sim,
        deterministic: true,
        hot_key_k: 4,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 8, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|_| {
            Box::new(|ps: &mut PsClient, c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[1.0]);
                ps.inc((0, 1 + (c as u64 % 7)), &[1.0]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 14);

    let hot: Vec<(&str, u64)> = report.shard_metrics[0]
        .iter()
        .filter(|(n, _)| n.starts_with("hot.u."))
        .map(|(n, v)| (n.as_str(), *v))
        .collect();
    assert!(!hot.is_empty(), "no hot.u entries harvested");
    let (top_name, top_count) = hot
        .iter()
        .max_by_key(|(_, c)| *c)
        .copied()
        .expect("sketch empty");
    assert_eq!(top_name, "hot.u.0:0", "wrong heavy hitter: {hot:?}");
    // Row 0 saw 7x the traffic of any tail row. Space-saving inflates
    // an evicted-slot estimate by at most N/k (= 14 here) over a tail
    // key's true count of 4, still well under the head's exact 28 —
    // strict dominance must hold.
    for (name, count) in &hot {
        if *name != "hot.u.0:0" {
            assert!(
                top_count > *count,
                "head not dominant: {top_name}={top_count} vs {name}={count}"
            );
        }
    }
    // GET-side sketch saw traffic too (row 0 is the only key read).
    assert!(
        report.shard_metrics[0]
            .iter()
            .any(|(n, v)| n == "hot.g.0:0" && *v > 0),
        "hot.g.0:0 missing from {:?}",
        report.shard_metrics[0]
    );
}

// ------------------------------------- multi-process merged Chrome trace

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_essptable")
}

fn out_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esspt-spans-{}-{tag}", std::process::id()))
}

#[test]
fn run_cluster_merges_a_cross_process_chrome_trace_and_serves_hot_keys() {
    // 2 shard + 2 worker OS processes, every frame sampled. A seeded
    // pause holds shard 1 for 2.5s at clock 3 so the run is still in
    // flight while this test scrapes shard 0's hot-key sketch; after
    // exit, the launcher-merged Chrome trace must contain the same
    // trace id under two distinct pids — a request timeline crossing a
    // real process boundary.
    const SHARDS: usize = 2;
    const WORKERS: usize = 2;
    let out = out_dir("merge");
    std::fs::create_dir_all(&out).unwrap();
    let spans_path = out.join("spans.json");
    let mut child = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--clocks",
            "10",
            "--consistency",
            "bsp",
            "--metrics",
            "true",
            "--trace-spans",
            spans_path.to_str().unwrap(),
            "--span-sample",
            "1",
            "--hot-keys",
            "4",
            "--fault-plan",
            "pause=s1@3:2500ms",
            "--out",
            out.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawning run-cluster");

    // Collect the admin-port map the launcher prints before spawning,
    // then drain stdout on a thread so the child never blocks.
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut shard_addrs: Vec<String> = Vec::new();
    let mut worker_addrs: Vec<String> = Vec::new();
    let mut line = String::new();
    while shard_addrs.len() + worker_addrs.len() < SHARDS + WORKERS {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "run-cluster exited before printing the admin-port map"
        );
        if let Some(rest) = line.trim().strip_prefix("metrics: shard ") {
            shard_addrs.push(rest.split(" -> ").nth(1).unwrap().to_string());
        } else if let Some(rest) = line.trim().strip_prefix("metrics: worker ") {
            worker_addrs.push(rest.split(" -> ").nth(1).unwrap().to_string());
        }
    }
    let drain = std::thread::spawn(move || {
        let mut rest = String::new();
        use std::io::Read;
        let _ = reader.read_to_string(&mut rest);
        rest
    });

    // Mid-run: shard 0's /json must eventually carry hot-key sketch
    // entries (hot.u.* — logreg pushes gradients every clock).
    let tick = Duration::from_millis(400);
    let deadline = Instant::now() + Duration::from_secs(20);
    let shard0 = &shard_addrs[0];
    let mut saw_hot = false;
    while !saw_hot {
        assert!(
            Instant::now() < deadline,
            "shard 0 never served a hot-key entry"
        );
        if let Ok(body) = scrape(shard0, "/json", tick) {
            let doc = Json::parse(&body).expect("shard /json must parse");
            for n in doc.get("nodes").unwrap().as_arr().unwrap() {
                if let Ok(metrics) = n.get("metrics").and_then(|m| m.as_obj()) {
                    if metrics.keys().any(|k| k.starts_with("hot.u.")) {
                        saw_hot = true;
                    }
                }
            }
        }
        if !saw_hot {
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    let status = child.wait().expect("waiting for run-cluster");
    let tail = drain.join().unwrap();
    assert!(status.success(), "run-cluster failed: {status}\n{tail}");

    // The launcher merged every per-process span dump into one file.
    let body = std::fs::read_to_string(&spans_path)
        .unwrap_or_else(|e| panic!("merged trace {spans_path:?} unreadable: {e}"));
    let doc = Json::parse(&body).expect("merged Chrome trace must parse");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "merged trace has no events");

    // Process lanes: one process_name metadata record per child, for
    // both roles.
    let mut labels = HashSet::new();
    for ev in events {
        if ev.get("name").and_then(|n| n.as_str()).ok() == Some("process_name") {
            let name = ev.get("args").unwrap().get("name").unwrap().as_str().unwrap();
            labels.insert(name.to_string());
        }
    }
    assert!(labels.contains("shard 0"), "labels: {labels:?}");
    assert!(labels.contains("worker 0"), "labels: {labels:?}");

    // The causal payoff: some trace id appears under >= 2 distinct pids
    // — the same sampled request timed on both sides of a process
    // boundary.
    let mut pids_by_trace: HashMap<String, HashSet<u64>> = HashMap::new();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()).ok() != Some("X") {
            continue;
        }
        let trace = ev
            .get("args")
            .unwrap()
            .get("trace")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        let pid = ev.get("pid").unwrap().as_u64().unwrap();
        pids_by_trace.entry(trace).or_default().insert(pid);
    }
    assert!(
        pids_by_trace.values().any(|p| p.len() >= 2),
        "no trace id crossed a process boundary ({} traces)",
        pids_by_trace.len()
    );
    std::fs::remove_dir_all(&out).ok();
}
