//! Telemetry-plane integration: the observability layer must be strictly
//! out-of-band — and actually observable.
//!
//!   * deterministic runs are bit-identical with the full telemetry
//!     stack on (StatsPull polling, event tracing) vs off, over both
//!     transports and across consistency models — the sensors never
//!     steer the protocol;
//!   * `RunReport` surfaces the new signals: read-latency quantiles,
//!     per-shard queue high-water marks, harvested shard registries, and
//!     a zero staleness-violation tripwire;
//!   * a genuine multi-process cluster (`run-cluster --metrics true`) is
//!     scrapeable MID-RUN: the launcher prints the admin-port map before
//!     spawning, both the JSON and Prometheus renderings parse, counters
//!     are monotone and nonzero, and the final params still match the
//!     single-process run to the bit;
//!   * `--trace-dir` leaves a JSONL flight record naming migrations,
//!     placement activations, fault firings, and replica promotions.

use std::collections::HashMap;
use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use essptable::apps::logreg::{run_logreg, LogRegConfig, W_TABLE};
use essptable::ps::checkpoint;
use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::telemetry::admin::scrape;
use essptable::telemetry::trace::TraceRing;
use essptable::transport::TransportSel;
use essptable::util::json::Json;

const WORKERS: usize = 4;
const SHARDS: usize = 2;

fn assert_bit_identical(ctx: &str, a: &HashMap<Key, Vec<f32>>, b: &HashMap<Key, Vec<f32>>) {
    assert_eq!(a.len(), b.len(), "{ctx}: row sets differ");
    for (k, va) in a {
        let vb = b
            .get(k)
            .unwrap_or_else(|| panic!("{ctx}: row {k:?} missing"));
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: row {k:?} elem {i} differs: {x} vs {y}"
            );
        }
    }
}

// ------------------------------------------------ out-of-band, in-process

/// The order-sensitive fractional counter from the transport matrix, with
/// the telemetry plane optionally at full blast: per-clock StatsPull
/// polling and an event-trace ring shared by every node.
fn counter_run(
    transport: TransportSel,
    consistency: Consistency,
    telemetry: bool,
) -> HashMap<Key, Vec<f32>> {
    let workers = 3;
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: SHARDS,
        consistency,
        transport,
        deterministic: true,
        stats_pull_every: if telemetry { 1 } else { 0 },
        trace: telemetry.then(|| Arc::new(TraceRing::new(4096))),
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[0.1 * (w + 1) as f32]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, 6).table_rows
}

#[test]
fn telemetry_at_full_blast_is_bit_identical_to_telemetry_off() {
    // The tentpole's out-of-band claim, as a test: per-clock wire-shipped
    // stats polling plus event tracing must not perturb a single bit of
    // the deterministic result, for every model class over both planes.
    let models = [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Vap { v0: 100.0 },
    ];
    for consistency in models {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!("{} over {}", consistency.label(), transport.label());
            let plain = counter_run(transport, consistency, false);
            let telemetered = counter_run(transport, consistency, true);
            assert_bit_identical(&label, &plain, &telemetered);
        }
    }
}

#[test]
fn run_report_surfaces_latency_backlog_and_staleness_signals() {
    let mut cluster = Cluster::new(ClusterConfig {
        workers: WORKERS,
        shards: SHARDS,
        consistency: Consistency::Essp { s: 2 },
        transport: TransportSel::Tcp,
        stats_pull_every: 2,
        trace: Some(Arc::new(TraceRing::new(1024))),
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..WORKERS)
        .map(|_| {
            Box::new(|ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[1.0]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 8);
    // Reads happened and their latency distribution is well-formed.
    assert!(report.read_latency.count > 0, "no read latencies recorded");
    assert!(report.read_latency.quantile(0.50) <= report.read_latency.quantile(0.999));
    // One backlog high-water mark per shard.
    assert_eq!(report.shard_queue_hwm.len(), SHARDS);
    // The satellite-1 tripwire: a healthy run bounds every read.
    assert_eq!(report.staleness_violations, 0, "staleness bound violated");
    // Harvested registries carry live counters (served GETs, commits).
    assert_eq!(report.shard_metrics.len(), SHARDS);
    for (i, entries) in report.shard_metrics.iter().enumerate() {
        let get = |name: &str| {
            entries
                .iter()
                .find(|(n, _)| n == name)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("shard {i}: metric {name} missing"))
        };
        assert!(get("gets_served") > 0, "shard {i} served no GETs");
        assert!(get("commits") > 0, "shard {i} committed no clocks");
        assert!(get("stats_pulls") > 0, "shard {i} was never polled");
    }
}

// ---------------------------------------------- multi-process scrapeable

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_essptable")
}

fn out_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esspt-telem-{}-{tag}", std::process::id()))
}

/// Read one JSON counter off a scraped `/json` document:
/// `nodes[] -> {node, metrics: {name: value}}`.
fn json_counter(doc: &Json, node: &str, name: &str) -> Option<u64> {
    for n in doc.get("nodes").and_then(|n| n.as_arr()).ok()? {
        if n.get("node").and_then(|s| s.as_str()).ok() == Some(node) {
            return n
                .get("metrics")
                .and_then(|m| m.get(name))
                .and_then(|v| v.as_u64())
                .ok();
        }
    }
    None
}

#[test]
fn multiprocess_cluster_is_scrapeable_mid_run_and_stays_bit_exact() {
    // 2 shard + 4 worker OS processes with admin sockets. A seeded pause
    // fault holds shard 1 for 2.5s at clock 3, guaranteeing the run is
    // still in flight while this test scrapes; the pause only stretches
    // wall time, so deterministic BSP params must still match the
    // undisturbed single-process run to the bit.
    let out = out_dir("scrape");
    std::fs::create_dir_all(&out).unwrap();
    let mut child = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--clocks",
            "10",
            "--consistency",
            "bsp",
            "--metrics",
            "true",
            "--fault-plan",
            "pause=s1@3:2500ms",
            "--out",
            out.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawning run-cluster");

    // The launcher prints the full admin-port map before spawning any
    // child process; collect it, then keep draining stdout on a thread so
    // the child can never block on a full pipe.
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut shard_addrs: Vec<String> = Vec::new();
    let mut worker_addrs: Vec<String> = Vec::new();
    let mut line = String::new();
    while shard_addrs.len() + worker_addrs.len() < SHARDS + WORKERS {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "run-cluster exited before printing the admin-port map"
        );
        if let Some(rest) = line.trim().strip_prefix("metrics: shard ") {
            shard_addrs.push(rest.split(" -> ").nth(1).unwrap().to_string());
        } else if let Some(rest) = line.trim().strip_prefix("metrics: worker ") {
            worker_addrs.push(rest.split(" -> ").nth(1).unwrap().to_string());
        }
    }
    let drain = std::thread::spawn(move || {
        let mut rest = String::new();
        use std::io::Read;
        let _ = reader.read_to_string(&mut rest);
        rest
    });

    // Poll shard 0's JSON endpoint until the run is visibly under way.
    let tick = Duration::from_millis(400);
    let deadline = Instant::now() + Duration::from_secs(20);
    let shard0 = &shard_addrs[0];
    let mut first = None;
    while first.is_none() {
        assert!(Instant::now() < deadline, "shard 0 never became scrapeable");
        if let Ok(body) = scrape(shard0, "/json", tick) {
            let doc = Json::parse(&body).expect("shard /json must parse");
            match json_counter(&doc, "shard0", "gets_served") {
                Some(g) if g > 0 => first = Some(g),
                _ => std::thread::sleep(Duration::from_millis(30)),
            }
        } else {
            std::thread::sleep(Duration::from_millis(30));
        }
    }
    // Counters are monotone across scrapes of a live process.
    let body = scrape(shard0, "/json", tick).expect("second scrape failed");
    let doc = Json::parse(&body).expect("second /json must parse");
    let second = json_counter(&doc, "shard0", "gets_served").unwrap();
    assert!(
        second >= first.unwrap(),
        "gets_served went backwards: {} -> {second}",
        first.unwrap()
    );
    // The Prometheus rendering of the same registry.
    let text = scrape(shard0, "/metrics", tick).expect("text scrape failed");
    assert!(
        text.contains("esspt_gets_served{node=\"shard0\"}"),
        "prometheus text missing the shard counter:\n{text}"
    );
    // Worker endpoints are live too, with the worker's own registry.
    let wbody = scrape(&worker_addrs[0], "/json", tick).expect("worker scrape failed");
    let wdoc = Json::parse(&wbody).expect("worker /json must parse");
    assert!(
        json_counter(&wdoc, "worker0", "gets").is_some(),
        "worker0 registry missing from its own endpoint:\n{wbody}"
    );

    let status = child.wait().expect("waiting for run-cluster");
    let tail = drain.join().unwrap();
    assert!(status.success(), "run-cluster failed: {status}\n{tail}");

    // The observed run still folds to the exact single-process result.
    let mut rows = HashMap::new();
    for i in 0..SHARDS {
        rows.extend(checkpoint::load(&out.join(format!("shard_{i}.ckp"))).unwrap());
    }
    std::fs::remove_dir_all(&out).ok();
    let (report, _) = run_logreg(
        ClusterConfig {
            workers: WORKERS,
            shards: SHARDS,
            consistency: Consistency::Bsp,
            transport: TransportSel::Sim,
            deterministic: true,
            ..Default::default()
        },
        LogRegConfig::default(),
        10,
    );
    assert_bit_identical("scraped multiprocess bsp", &report.table_rows, &rows);
    let w = &rows[&(W_TABLE, 0)];
    assert!(w.iter().any(|x| *x != 0.0), "weights never updated");
}

// --------------------------------------------------- JSONL flight records

/// Concatenated contents of every trace file in `dir` matching `prefix`,
/// with each non-empty line checked to be a well-formed trace record.
fn read_traces(dir: &Path, prefix: &str) -> String {
    let mut all = String::new();
    let mut found = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_str().unwrap().to_string();
        if !name.starts_with(prefix) {
            continue;
        }
        found += 1;
        let body = std::fs::read_to_string(&path).unwrap();
        for l in body.lines().filter(|l| !l.trim().is_empty()) {
            let rec =
                Json::parse(l).unwrap_or_else(|e| panic!("{name}: bad JSONL line {l}: {e}"));
            rec.get("kind")
                .and_then(|k| k.as_str())
                .unwrap_or_else(|e| panic!("{name}: record without kind: {e}"));
            rec.get("node")
                .unwrap_or_else(|e| panic!("{name}: record without node: {e}"));
        }
        all.push_str(&body);
    }
    assert!(found > 0, "no {prefix}* files in {dir:?}");
    all
}

fn run_cluster_traced(tag: &str, extra: &[&str]) -> PathBuf {
    let out = out_dir(&format!("{tag}-out"));
    let traces = out_dir(&format!("{tag}-traces"));
    std::fs::create_dir_all(&out).unwrap();
    let mut args = vec![
        "run-cluster",
        "--app",
        "logreg",
        "--workers",
        "4",
        "--clocks",
        "10",
        "--consistency",
        "bsp",
    ];
    args.extend_from_slice(extra);
    let traces_s = traces.to_str().unwrap().to_string();
    let out_s = out.to_str().unwrap().to_string();
    args.extend_from_slice(&["--trace-dir", traces_s.as_str(), "--out", out_s.as_str()]);
    let status = Command::new(bin())
        .args(&args)
        .stdout(Stdio::null())
        .status()
        .expect("spawning traced run-cluster");
    assert!(status.success(), "traced run-cluster {tag} failed: {status}");
    std::fs::remove_dir_all(&out).ok();
    traces
}

#[test]
fn trace_out_documents_a_live_migration() {
    // 4 provisioned shards, 2 active, grown at clock 4: the shard-side
    // flight records must name the fence protocol, and the worker-side
    // ones the placement epoch they switched to.
    let traces = run_cluster_traced(
        "mig",
        &["--shards", "4", "--active", "2", "--migrate-at", "4"],
    );
    let shard_log = read_traces(&traces, "trace_shard_");
    for kind in ["migrate_begin", "migrate_handoff"] {
        assert!(
            shard_log.contains(&format!("\"kind\":\"{kind}\"")),
            "shard traces missing {kind}:\n{shard_log}"
        );
    }
    let worker_log = read_traces(&traces, "trace_worker_");
    assert!(
        worker_log.contains("\"kind\":\"placement_activate\""),
        "worker traces missing placement_activate:\n{worker_log}"
    );
    std::fs::remove_dir_all(&traces).ok();
}

#[test]
fn trace_out_documents_a_kill_and_the_replica_promotion() {
    // The seeded kill at clock 4 fires on primary 0; its dying trace dump
    // must record the fault, and the replica's must record the takeover.
    let traces = run_cluster_traced(
        "kill",
        &[
            "--shards",
            &SHARDS.to_string(),
            "--replicas",
            "1",
            "--fault-plan",
            "kill=s0@4",
        ],
    );
    let shard_log = read_traces(&traces, "trace_shard_");
    for kind in ["fault_kill", "promotion_sent", "promotion"] {
        assert!(
            shard_log.contains(&format!("\"kind\":\"{kind}\"")),
            "shard traces missing {kind}:\n{shard_log}"
        );
    }
    std::fs::remove_dir_all(&traces).ok();
}
