//! Transport integration: the real TCP data plane must be a drop-in
//! substitution for the simulated one at the paper's testbed boundary.
//!
//!   * loopback-TCP cluster runs (same threads, real sockets) reproduce
//!     the in-process SimNet result bit-exactly under deterministic BSP;
//!   * a genuine multi-process cluster (OS processes spawned via the
//!     `serve-shard` / `run-worker` / `run-cluster` subcommands) runs
//!     logreg to completion under BSP, SSP and ESSP, and the BSP run's
//!     final parameters match the single-process run to the bit.

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::Command;

use essptable::apps::logreg::{run_logreg, LogRegConfig, W_TABLE};
use essptable::ps::checkpoint;
use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::transport::TransportSel;

const WORKERS: usize = 4;
const SHARDS: usize = 2;

fn run_logreg_once(
    transport: TransportSel,
    consistency: Consistency,
    clocks: u64,
) -> HashMap<Key, Vec<f32>> {
    let (report, _) = run_logreg(
        ClusterConfig {
            workers: WORKERS,
            shards: SHARDS,
            consistency,
            transport,
            deterministic: true,
            ..Default::default()
        },
        LogRegConfig::default(),
        clocks,
    );
    report.table_rows
}

fn assert_bit_identical(a: &HashMap<Key, Vec<f32>>, b: &HashMap<Key, Vec<f32>>) {
    assert_eq!(a.len(), b.len(), "row sets differ");
    for (k, va) in a {
        let vb = b.get(k).unwrap_or_else(|| panic!("row {k:?} missing"));
        assert_eq!(va.len(), vb.len(), "row {k:?} length differs");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "row {k:?} elem {i} differs: {x} vs {y}"
            );
        }
    }
}

// --------------------------------------------------- loopback, in-process

#[test]
fn tcp_loopback_matches_simnet_bit_exact_under_bsp() {
    let sim = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 8);
    let tcp = run_logreg_once(TransportSel::Tcp, Consistency::Bsp, 8);
    assert_bit_identical(&sim, &tcp);
    // And the weights actually moved (the run did real work).
    let w = &sim[&(W_TABLE, 0)];
    assert!(w.iter().any(|x| *x != 0.0), "weights never updated");
}

#[test]
fn tcp_loopback_ssp_trains_to_completion() {
    let rows = run_logreg_once(TransportSel::Tcp, Consistency::Ssp { s: 2 }, 8);
    let w = &rows[&(W_TABLE, 0)];
    assert!(w.iter().all(|x| x.is_finite()));
    assert!(w.iter().any(|x| *x != 0.0));
}

#[test]
fn tcp_loopback_essp_pushes_and_counts_exactly() {
    // Counter workload: exact-integer increments make "no update lost"
    // checkable regardless of float order; ESSP must actually push.
    let mut cluster = Cluster::new(ClusterConfig {
        workers: WORKERS,
        shards: SHARDS,
        consistency: Consistency::Essp { s: 2 },
        transport: TransportSel::Tcp,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..WORKERS)
        .map(|_| {
            Box::new(|ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[1.0]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 10);
    assert_eq!(report.table_rows[&(0, 0)][0], (WORKERS * 10) as f32);
    assert!(
        report.shard_stats.iter().any(|s| s.push_waves > 0),
        "ESSP never pushed over TCP"
    );
    // Real frames crossed the wire and were all accounted for.
    assert!(report.net_messages > 0);
    assert!(report.net_bytes > 0);
}

// ------------------------------------------------------- multi-process

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_essptable")
}

fn out_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esspt-dist-{}-{tag}", std::process::id()))
}

/// Launch a full multi-process cluster (2 shards + 4 workers as OS
/// processes over loopback TCP) and return the merged final tables.
fn run_cluster_processes(consistency: &str, clocks: u64, tag: &str) -> HashMap<Key, Vec<f32>> {
    let out = out_dir(tag);
    std::fs::create_dir_all(&out).unwrap();
    let status = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--clocks",
            &clocks.to_string(),
            "--consistency",
            consistency,
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning run-cluster");
    assert!(status.success(), "run-cluster {consistency} failed: {status}");
    let mut rows = HashMap::new();
    for i in 0..SHARDS {
        let dump = out.join(format!("shard_{i}.ckp"));
        rows.extend(checkpoint::load(&dump).expect("loading shard dump"));
    }
    std::fs::remove_dir_all(&out).ok();
    rows
}

#[test]
fn multiprocess_bsp_matches_single_process_bit_exact() {
    let dist = run_cluster_processes("bsp", 10, "bsp");
    let local = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 10);
    assert_bit_identical(&local, &dist);
}

#[test]
fn multiprocess_ssp_and_essp_run_to_completion() {
    for (consistency, tag) in [("ssp:2", "ssp"), ("essp:2", "essp")] {
        let rows = run_cluster_processes(consistency, 8, tag);
        let w = rows
            .get(&(W_TABLE, 0))
            .unwrap_or_else(|| panic!("{consistency}: weight row missing"));
        assert!(
            w.iter().all(|x| x.is_finite()),
            "{consistency}: non-finite weights"
        );
        assert!(
            w.iter().any(|x| *x != 0.0),
            "{consistency}: weights never updated"
        );
    }
}

#[test]
fn multiprocess_vap_is_rejected_with_guidance() {
    let out = out_dir("vap");
    std::fs::create_dir_all(&out).unwrap();
    let output = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "counter",
            "--consistency",
            "vap:0.5",
            "--out",
            out.to_str().unwrap(),
        ])
        .output()
        .expect("spawning run-cluster");
    assert!(!output.status.success(), "vap must not launch cross-process");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        stderr.contains("global synchronization"),
        "unhelpful error: {stderr}"
    );
    std::fs::remove_dir_all(&out).ok();
}
