//! Transport integration: the real TCP data plane must be a drop-in
//! substitution for the simulated one at the paper's testbed boundary.
//!
//!   * loopback-TCP cluster runs (same threads, real sockets) reproduce
//!     the in-process SimNet result bit-exactly under deterministic BSP;
//!   * the transport matrix: *every* consistency model — including the
//!     value-bounded VAP/AVAP, whose enforcement is now wire-distributed
//!     — produces bit-identical final parameters under `deterministic`
//!     mode over both `sim` and `tcp`;
//!   * the wire-v7 delta-wave A/B: for every model, over both planes, a
//!     run whose eager waves ship delta chains matches the same run with
//!     `snapshot_waves` forcing full snapshots bit-for-bit — with and
//!     without a mid-run migration (RowHandoff chain resets included);
//!   * a genuine multi-process cluster (OS processes spawned via the
//!     `serve-shard` / `run-worker` / `run-cluster` subcommands) runs
//!     logreg to completion under BSP, SSP, ESSP, VAP and AVAP, and the
//!     BSP run's final parameters match the single-process run to the
//!     bit. The PR-2 "vap cannot run across OS processes" rejection is
//!     gone: the policy layer replaced the process-global tracker with
//!     shard-local ledgers plus NormReport/Bound/Detach wire messages.

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::Command;

use essptable::apps::logreg::{run_logreg, LogRegConfig, W_TABLE};
use essptable::ps::checkpoint;
use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, MigrationSpec, PsApp, RunReport, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::transport::TransportSel;

const WORKERS: usize = 4;
const SHARDS: usize = 2;

fn run_logreg_once(
    transport: TransportSel,
    consistency: Consistency,
    clocks: u64,
) -> HashMap<Key, Vec<f32>> {
    let (report, _) = run_logreg(
        ClusterConfig {
            workers: WORKERS,
            shards: SHARDS,
            consistency,
            transport,
            deterministic: true,
            ..Default::default()
        },
        LogRegConfig::default(),
        clocks,
    );
    report.table_rows
}

fn assert_bit_identical(ctx: &str, a: &HashMap<Key, Vec<f32>>, b: &HashMap<Key, Vec<f32>>) {
    assert_eq!(a.len(), b.len(), "{ctx}: row sets differ");
    for (k, va) in a {
        let vb = b
            .get(k)
            .unwrap_or_else(|| panic!("{ctx}: row {k:?} missing"));
        assert_eq!(va.len(), vb.len(), "{ctx}: row {k:?} length differs");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: row {k:?} elem {i} differs: {x} vs {y}"
            );
        }
    }
}

// --------------------------------------------------- loopback, in-process

#[test]
fn tcp_loopback_matches_simnet_bit_exact_under_bsp() {
    let sim = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 8);
    let tcp = run_logreg_once(TransportSel::Tcp, Consistency::Bsp, 8);
    assert_bit_identical("bsp logreg", &sim, &tcp);
    // And the weights actually moved (the run did real work).
    let w = &sim[&(W_TABLE, 0)];
    assert!(w.iter().any(|x| *x != 0.0), "weights never updated");
}

/// Order-sensitive float counter: worker w adds 0.1 * (w + 1) to one
/// shared row every clock, so the final value depends on float summation
/// order — which deterministic mode pins to sorted (clock, worker)
/// replay, independent of transport timing. A second, wide table takes
/// fractional *sparse* INCs (2 of 64 indices per worker per clock), so
/// the matrix also proves the sparse delta path — pair coalescing, the
/// wire-v3 sparse row arm, sparse apply, sparse staged previews, and
/// sparse-part norm reports under VAP/AVAP — is bit-deterministic across
/// both transports. A third row, written only by worker 0 and read by
/// everyone else, gives ESSP's wire-v7 delta chains pure readers to ship
/// to; `snapshot_waves` is the A/B control forcing full-snapshot waves.
fn fractional_counter_run(
    transport: TransportSel,
    consistency: Consistency,
    snapshot_waves: bool,
) -> RunReport {
    let workers = 3;
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: SHARDS,
        consistency,
        transport,
        deterministic: true,
        snapshot_waves,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    cluster.add_table(TableSpec::zeros(1, 2, 64));
    cluster.add_table(TableSpec::zeros(2, 2, 8));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[0.1 * (w + 1) as f32]);
                let _ = ps.get((1, 0));
                ps.inc_sparse(
                    (1, 0),
                    &[(w, 0.1 * (w + 1) as f32), (17 + w, 0.01)],
                );
                let _ = ps.get((2, 0));
                if w == 0 {
                    ps.inc_sparse((2, 0), &[(0, 0.5), (3, 0.25)]);
                }
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, 6)
}

#[test]
fn transport_matrix_every_model_deterministic_bit_identical() {
    // The transport matrix: every consistency model — including the
    // value-bounded ones, runnable over TCP since the policy layer made
    // their enforcement wire-distributed — completes over both data
    // planes with bit-identical final parameters under deterministic
    // mode. (Loose v0: the gate engages rarely, so the test exercises
    // the protocol without stall-bound runtimes.)
    let models = [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Async { refresh_every: 1 },
        Consistency::Vap { v0: 100.0 },
        Consistency::Avap { v0: 100.0, s: 2 },
    ];
    for consistency in models {
        let label = consistency.label();
        let sim = fractional_counter_run(TransportSel::Sim, consistency, false).table_rows;
        let tcp = fractional_counter_run(TransportSel::Tcp, consistency, false).table_rows;
        assert_bit_identical(&label, &sim, &tcp);
        // Sanity: all 18 increments of 0.1/0.2/0.3 landed.
        let v = sim[&(0, 0)][0];
        assert!(
            (v - 3.6).abs() < 1e-3,
            "{label}: expected ~3.6 total, got {v}"
        );
        // And the sparse INCs landed exactly where aimed: worker w's mass
        // at index w (6 clocks x 0.1*(w+1)) and 0.01x6 at index 17+w —
        // nothing anywhere else.
        let row = &sim[&(1, 0)];
        for w in 0..3 {
            assert!(
                (row[w] - 0.6 * (w + 1) as f32).abs() < 1e-3,
                "{label}: sparse index {w} = {}",
                row[w]
            );
            assert!((row[17 + w] - 0.06).abs() < 1e-3, "{label}: index {}", 17 + w);
        }
        let mass: f32 = row.iter().sum();
        assert!(
            (mass - (3.6 + 0.18)).abs() < 1e-2,
            "{label}: sparse row mass {mass}"
        );
    }
}

#[test]
fn delta_wave_matrix_every_model_bit_identical_to_snapshot_waves() {
    // The wire-v7 acceptance matrix: for every consistency model, over
    // both data planes, a run whose eager waves ship delta chains must
    // land on final parameters bit-identical to the same run with
    // `snapshot_waves` forcing every wave to a full snapshot. Chains
    // carry the interval's exact ordered deltas (never coalesced), so the
    // client fold replays the shard's own float summation order — the two
    // arms are the same computation expressed in two encodings.
    let models = [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Async { refresh_every: 1 },
        Consistency::Vap { v0: 100.0 },
        Consistency::Avap { v0: 100.0, s: 2 },
    ];
    for consistency in models {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!("{} over {}", consistency.label(), transport.label());
            let delta = fractional_counter_run(transport, consistency, false);
            let snap = fractional_counter_run(transport, consistency, true);
            assert_bit_identical(&label, &delta.table_rows, &snap.table_rows);
            let pushed = |r: &RunReport| -> u64 {
                r.shard_stats.iter().map(|s| s.rows_pushed_delta).sum()
            };
            // In deterministic mode only ESSP's commit waves ship chains
            // (VAP/AVAP's staged per-update previews stay snapshots); the
            // other models are controls proving the flag is inert there.
            if matches!(consistency, Consistency::Essp { .. }) {
                assert!(
                    pushed(&delta) > 0,
                    "{label}: delta arm never shipped a delta chain"
                );
            }
            assert_eq!(
                pushed(&snap),
                0,
                "{label}: snapshot_waves arm shipped delta chains"
            );
            // The pure-reader row saw all of worker 0's increments.
            let row = &delta.table_rows[&(2, 0)];
            assert!((row[0] - 3.0).abs() < 1e-3, "{label}: row[0] = {}", row[0]);
            assert!((row[3] - 1.5).abs() < 1e-3, "{label}: row[3] = {}", row[3]);
        }
    }
}

// ------------------------------------------------------- live migration

/// Deterministic logreg over 4 provisioned primaries (2 initially
/// active); `elastic` additionally schedules a mid-run migration at
/// clock 4 that grows the active set to 4 AND force-moves the weight row
/// to shard 3. Returns final params and the migrated-row count.
fn logreg_elastic_run(
    transport: TransportSel,
    consistency: Consistency,
    clocks: u64,
    elastic: bool,
) -> (HashMap<Key, Vec<f32>>, u64) {
    let migration = elastic.then(|| MigrationSpec {
        at_clock: 4,
        grow_to: Some(4),
        moves: vec![((W_TABLE, 0), 3)],
    });
    let (report, _) = run_logreg(
        ClusterConfig {
            workers: WORKERS,
            shards: 4,
            active_shards: 2,
            migration,
            consistency,
            transport,
            deterministic: true,
            ..Default::default()
        },
        LogRegConfig::default(),
        clocks,
    );
    let moved: u64 = report.shard_stats.iter().map(|s| s.rows_migrated_in).sum();
    (report.table_rows, moved)
}

#[test]
fn migration_logreg_bit_identical_to_unmigrated_run() {
    // The acceptance bar: a deterministic logreg run with a forced
    // 2->4-shard migration mid-run produces final params bit-identical
    // to the unmigrated run, over sim AND tcp. The clock-pinned read
    // models (BSP, and the s=0 window of SSP/ESSP, whose every read is
    // exactly the fold through c-1) make logreg's gradient stream —
    // hence its updates — identical in both runs; the migration then
    // merely changes WHERE each key's sorted fold happens, never its
    // order. Wider windows / value bounds admit timing-dependent reads,
    // so their bit-level proof runs on the read-independent counter
    // below (the repo's established matrix methodology).
    for consistency in [
        Consistency::Bsp,
        Consistency::Ssp { s: 0 },
        Consistency::Essp { s: 0 },
    ] {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!("{} over {}", consistency.label(), transport.label());
            let (plain, moved_plain) = logreg_elastic_run(transport, consistency, 9, false);
            let (migrated, moved) = logreg_elastic_run(transport, consistency, 9, true);
            assert_eq!(moved_plain, 0, "{label}: unmigrated run moved rows");
            assert!(moved > 0, "{label}: migration moved nothing");
            assert_bit_identical(&label, &plain, &migrated);
        }
    }
}

/// The order-sensitive fractional counter (read-independent INCs) over
/// the elastic plane: every consistency model — VAP/AVAP's value waves
/// and Async's unbounded reads included — must fold bit-identically with
/// and without a mid-run migration, over both transports.
fn counter_elastic_run(
    transport: TransportSel,
    consistency: Consistency,
    migrate: bool,
    snapshot_waves: bool,
) -> HashMap<Key, Vec<f32>> {
    let workers = 3;
    let migration = migrate.then(|| MigrationSpec {
        at_clock: 3,
        grow_to: Some(4),
        moves: vec![((0, 0), 3), ((1, 0), 2), ((2, 0), 3)],
    });
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: 4,
        active_shards: 2,
        migration,
        consistency,
        transport,
        deterministic: true,
        snapshot_waves,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    cluster.add_table(TableSpec::zeros(1, 2, 64));
    cluster.add_table(TableSpec::zeros(2, 2, 8));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[0.1 * (w + 1) as f32]);
                let _ = ps.get((1, 0));
                ps.inc_sparse((1, 0), &[(w, 0.1 * (w + 1) as f32), (17 + w, 0.01)]);
                // Pure-reader row for workers 1 and 2: ESSP waves ship it
                // as wire-v7 delta chains, re-seeded across its mid-run
                // move to shard 3 (RowHandoff carries the live chain).
                let _ = ps.get((2, 0));
                if w == 0 {
                    ps.inc_sparse((2, 0), &[(0, 0.5), (3, 0.25)]);
                }
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, 6).table_rows
}

#[test]
fn migration_matrix_every_model_counter_bit_identical() {
    let models = [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Async { refresh_every: 1 },
        Consistency::Vap { v0: 100.0 },
        Consistency::Avap { v0: 100.0, s: 2 },
    ];
    for consistency in models {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!("{} over {}", consistency.label(), transport.label());
            let plain = counter_elastic_run(transport, consistency, false, false);
            let migrated = counter_elastic_run(transport, consistency, true, false);
            assert_bit_identical(&label, &plain, &migrated);
            // Sanity: the 18 fractional increments all landed.
            let v = migrated[&(0, 0)][0];
            assert!((v - 3.6).abs() < 1e-3, "{label}: expected ~3.6, got {v}");
        }
    }
}

#[test]
fn delta_wave_migration_matrix_bit_identical_to_snapshot_waves() {
    // Wire-v7 chains across a mid-run migration: the pure-reader row
    // (2, 0) moves to shard 3 at clock 3, exercising the RowHandoff
    // chain-reset rules (departure and arrival both downgrade to a
    // seeding snapshot, then chains resume on the new owner). The delta
    // arm must match the forced-snapshot arm to the bit for every model,
    // over both transports.
    let models = [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Async { refresh_every: 1 },
        Consistency::Vap { v0: 100.0 },
        Consistency::Avap { v0: 100.0, s: 2 },
    ];
    for consistency in models {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!("{} over {} migrated", consistency.label(), transport.label());
            let delta = counter_elastic_run(transport, consistency, true, false);
            let snap = counter_elastic_run(transport, consistency, true, true);
            assert_bit_identical(&label, &delta, &snap);
            let row = &delta[&(2, 0)];
            assert!((row[0] - 3.0).abs() < 1e-3, "{label}: row[0] = {}", row[0]);
        }
    }
}

#[test]
fn tcp_loopback_ssp_trains_to_completion() {
    let rows = run_logreg_once(TransportSel::Tcp, Consistency::Ssp { s: 2 }, 8);
    let w = &rows[&(W_TABLE, 0)];
    assert!(w.iter().all(|x| x.is_finite()));
    assert!(w.iter().any(|x| *x != 0.0));
}

#[test]
fn tcp_loopback_essp_pushes_and_counts_exactly() {
    // Counter workload: exact-integer increments make "no update lost"
    // checkable regardless of float order; ESSP must actually push.
    let mut cluster = Cluster::new(ClusterConfig {
        workers: WORKERS,
        shards: SHARDS,
        consistency: Consistency::Essp { s: 2 },
        transport: TransportSel::Tcp,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    let apps: Vec<Box<dyn PsApp>> = (0..WORKERS)
        .map(|_| {
            Box::new(|ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[1.0]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 10);
    assert_eq!(report.table_rows[&(0, 0)][0], (WORKERS * 10) as f32);
    assert!(
        report.shard_stats.iter().any(|s| s.push_waves > 0),
        "ESSP never pushed over TCP"
    );
    // Real frames crossed the wire and were all accounted for.
    assert!(report.net_messages > 0);
    assert!(report.net_bytes > 0);
}

// ------------------------------------------------------- multi-process

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_essptable")
}

fn out_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esspt-dist-{}-{tag}", std::process::id()))
}

/// Launch a full multi-process cluster (2 shards + 4 workers as OS
/// processes over loopback TCP) and return the merged final tables.
fn run_cluster_processes(consistency: &str, clocks: u64, tag: &str) -> HashMap<Key, Vec<f32>> {
    let out = out_dir(tag);
    std::fs::create_dir_all(&out).unwrap();
    let status = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--clocks",
            &clocks.to_string(),
            "--consistency",
            consistency,
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning run-cluster");
    assert!(status.success(), "run-cluster {consistency} failed: {status}");
    let mut rows = HashMap::new();
    for i in 0..SHARDS {
        let dump = out.join(format!("shard_{i}.ckp"));
        rows.extend(checkpoint::load(&dump).expect("loading shard dump"));
    }
    std::fs::remove_dir_all(&out).ok();
    rows
}

#[test]
fn multiprocess_bsp_matches_single_process_bit_exact() {
    let dist = run_cluster_processes("bsp", 10, "bsp");
    let local = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 10);
    assert_bit_identical("multiprocess bsp", &local, &dist);
}

#[test]
fn multiprocess_ssp_and_essp_run_to_completion() {
    for (consistency, tag) in [("ssp:2", "ssp"), ("essp:2", "essp")] {
        let rows = run_cluster_processes(consistency, 8, tag);
        let w = rows
            .get(&(W_TABLE, 0))
            .unwrap_or_else(|| panic!("{consistency}: weight row missing"));
        assert!(
            w.iter().all(|x| x.is_finite()),
            "{consistency}: non-finite weights"
        );
        assert!(
            w.iter().any(|x| *x != 0.0),
            "{consistency}: weights never updated"
        );
    }
}

#[test]
fn multiprocess_migration_matches_single_process_bit_exact() {
    // Four shard processes, two initially active, grown to four at clock
    // 4: the logreg weight row's hash home moves shard 0 -> 2, so its
    // RowHandoff crosses a real shard->shard socket (shards dial their
    // peers when a migration is armed). Deterministic BSP final params
    // are placement-independent — each key is one sorted (clock, worker)
    // fold wherever it lives — so the migrated multi-process run must
    // match the plain in-process SimNet run to the bit.
    let out = out_dir("mig");
    std::fs::create_dir_all(&out).unwrap();
    let status = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            "4",
            "--active",
            "2",
            "--migrate-at",
            "4",
            "--clocks",
            "10",
            "--consistency",
            "bsp",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning migrated run-cluster");
    assert!(status.success(), "migrated run-cluster failed: {status}");
    let mut rows = HashMap::new();
    let mut weight_home = None;
    for i in 0..4 {
        let dump = out.join(format!("shard_{i}.ckp"));
        let shard_rows = checkpoint::load(&dump).expect("loading shard dump");
        if shard_rows.contains_key(&(W_TABLE, 0)) {
            weight_home = Some(i);
        }
        rows.extend(shard_rows);
    }
    std::fs::remove_dir_all(&out).ok();
    assert_eq!(
        weight_home,
        Some(2),
        "the weight row's post-migration owner must hold it"
    );
    let local = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 10);
    assert_bit_identical("multiprocess migrated bsp", &local, &rows);
}

#[test]
fn multiprocess_replicated_cluster_trains_and_conserves() {
    // Replicas as real OS processes: 2 primaries x 1 replica each (4
    // shard processes). SSP pulls fan out to the replica processes; the
    // merged primary dumps must still train.
    let out = out_dir("repl");
    std::fs::create_dir_all(&out).unwrap();
    let status = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--replicas",
            "1",
            "--clocks",
            "8",
            "--consistency",
            "ssp:1",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning replicated run-cluster");
    assert!(status.success(), "replicated run-cluster failed: {status}");
    let mut rows = HashMap::new();
    for i in 0..SHARDS {
        let dump = out.join(format!("shard_{i}.ckp"));
        rows.extend(checkpoint::load(&dump).expect("loading shard dump"));
    }
    std::fs::remove_dir_all(&out).ok();
    let w = rows.get(&(W_TABLE, 0)).expect("weight row missing");
    assert!(w.iter().all(|x| x.is_finite()));
    assert!(w.iter().any(|x| *x != 0.0), "weights never updated");
}

#[test]
fn multiprocess_kill_promotes_replica_bit_exact() {
    // A real OS process dies: primary 0's serve-shard process is killed
    // by the seeded fault plan at clock 4. Nothing is pre-armed — the
    // run-cluster launcher runs the coordinator's failure detector over
    // a real TCP endpoint (heartbeat StatsPull polls to every shard
    // process), notices the victim's silence, and emits the Promote
    // delta itself. run-cluster hands the killed primary's --dump to
    // the replica process instead, so shard_0.ckp below is written by
    // the *promoted* node. The fold is placement-independent under
    // deterministic BSP: the merged result must match the undisturbed
    // single-process run to the bit.
    let out = out_dir("kill");
    std::fs::create_dir_all(&out).unwrap();
    let status = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--replicas",
            "1",
            "--fault-plan",
            "kill=s0@4",
            "--clocks",
            "10",
            "--consistency",
            "bsp",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning kill-faulted run-cluster");
    assert!(status.success(), "kill-faulted run-cluster failed: {status}");
    let mut rows = HashMap::new();
    for i in 0..SHARDS {
        let dump = out.join(format!("shard_{i}.ckp"));
        rows.extend(checkpoint::load(&dump).expect("loading shard dump"));
    }
    std::fs::remove_dir_all(&out).ok();
    let local = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 10);
    assert_bit_identical("multiprocess kill+promotion bsp", &local, &rows);
}

#[test]
fn multiprocess_wal_crash_recovers_bit_exact() {
    // The durable plane across OS processes: every shard process logs to
    // a WAL (--fsync commit), shard 0 loses its volatile state at clock 4
    // and recovers from checkpoint + log tail. Final params must match
    // the undisturbed single-process run to the bit.
    let out = out_dir("crash");
    let wal = out_dir("crash-wal");
    std::fs::create_dir_all(&out).unwrap();
    std::fs::create_dir_all(&wal).unwrap();
    let status = Command::new(bin())
        .args([
            "run-cluster",
            "--app",
            "logreg",
            "--workers",
            &WORKERS.to_string(),
            "--shards",
            &SHARDS.to_string(),
            "--wal",
            wal.to_str().unwrap(),
            "--fsync",
            "commit",
            "--fault-plan",
            "crash=s0@4",
            "--clocks",
            "10",
            "--consistency",
            "bsp",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning crash-faulted run-cluster");
    assert!(status.success(), "crash-faulted run-cluster failed: {status}");
    let mut rows = HashMap::new();
    for i in 0..SHARDS {
        let dump = out.join(format!("shard_{i}.ckp"));
        rows.extend(checkpoint::load(&dump).expect("loading shard dump"));
    }
    std::fs::remove_dir_all(&out).ok();
    std::fs::remove_dir_all(&wal).ok();
    let local = run_logreg_once(TransportSel::Sim, Consistency::Bsp, 10);
    assert_bit_identical("multiprocess wal crash-recover bsp", &local, &rows);
}

#[test]
fn multiprocess_vap_and_avap_run_to_completion() {
    // The PR-2 rejection path is gone: value-bounded models run as real
    // OS processes over TCP. The shard-local ledgers + NormReport/Bound/
    // Detach messages replace the process-global tracker; the logreg run
    // must complete and train.
    for (consistency, tag) in [("vap:50", "vap"), ("avap:50:2", "avap")] {
        let rows = run_cluster_processes(consistency, 6, tag);
        let w = rows
            .get(&(W_TABLE, 0))
            .unwrap_or_else(|| panic!("{consistency}: weight row missing"));
        assert!(
            w.iter().all(|x| x.is_finite()),
            "{consistency}: non-finite weights"
        );
        assert!(
            w.iter().any(|x| *x != 0.0),
            "{consistency}: weights never updated"
        );
    }
}
