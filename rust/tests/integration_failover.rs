//! Self-healing failover integration: the coordinator's failure
//! detector driving promotion, mid-flight worker repoint, WAL-fallback
//! recovery onto spares, and automatic re-replication.
//!
//! Unlike `integration_durability` (which proves the *placement
//! mechanics* are bit-invisible), these tests pin the *detection-driven*
//! properties of PR 9:
//!
//!   * nobody is pre-armed with the failure schedule — the run report
//!     must carry the detector's own account (confirmed deaths, the
//!     measured failover window, the emitted promotions);
//!   * workers survive the kill mid-flight (bounded GET retry + repoint)
//!     for every consistency model over both transports, with the
//!     staleness-violation tripwire at zero;
//!   * a double failure (replica first, then its primary) must not
//!     promote the dead replica — the coordinator falls back to a WAL
//!     rebuild on a fresh spare, and the clients' resend window closes
//!     the un-fsynced tail;
//!   * re-replication: after promotion a spare is caught up from the
//!     promoted primary behind an attach fence and ends bit-equal to it;
//!   * randomized chaos: seeded compound fault plans (kill + crash +
//!     pause + delay) all complete conserving the counter, printing any
//!     violating seed for replay.

use std::collections::HashMap;
use std::path::PathBuf;

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::durability::DurabilityConfig;
use essptable::ps::failover::FailoverConfig;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::sim::fault::FaultPlan;
use essptable::transport::TransportSel;
use essptable::util::rng::splitmix64;

const MODELS: [Consistency; 6] = [
    Consistency::Bsp,
    Consistency::Ssp { s: 2 },
    Consistency::Essp { s: 2 },
    Consistency::Async { refresh_every: 1 },
    Consistency::Vap { v0: 100.0 },
    Consistency::Avap { v0: 100.0, s: 2 },
];

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("esspt-failover-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The order-sensitive fractional counter (the repo's bit-determinism
/// probe): worker `w` adds 0.1*(w+1) to a dense row and two sparse
/// indices of a wide row each clock.
fn counter_run(cfg: ClusterConfig, clocks: u64) -> RunReport {
    let workers = cfg.workers;
    let mut cluster = Cluster::new(cfg);
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    cluster.add_table(TableSpec::zeros(1, 2, 64));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[0.1 * (w + 1) as f32]);
                let _ = ps.get((1, 0));
                ps.inc_sparse((1, 0), &[(w, 0.1 * (w + 1) as f32), (17 + w, 0.01)]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, clocks)
}

fn base_cfg(transport: TransportSel, consistency: Consistency, faults: &str) -> ClusterConfig {
    ClusterConfig {
        workers: 3,
        shards: 2,
        consistency,
        transport,
        deterministic: true,
        faults: FaultPlan::parse(faults).unwrap(),
        ..Default::default()
    }
}

fn assert_counter_landed(ctx: &str, rows: &HashMap<Key, Vec<f32>>, clocks: u64) {
    // 3 workers x clocks x 0.1*(w+1) = 0.6/clock in the dense row: the
    // run did the whole workload through the failover, nothing lost or
    // double-applied.
    let expect = 0.6 * clocks as f64;
    let v = rows[&(0, 0)][0] as f64;
    assert!(
        (v - expect).abs() < 1e-2,
        "{ctx}: expected ~{expect} total, got {v}"
    );
}

fn assert_bit_identical(ctx: &str, a: &HashMap<Key, Vec<f32>>, b: &HashMap<Key, Vec<f32>>) {
    assert_eq!(a.len(), b.len(), "{ctx}: row sets differ");
    for (k, va) in a {
        let vb = b
            .get(k)
            .unwrap_or_else(|| panic!("{ctx}: row {k:?} missing"));
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: row {k:?} elem {i} differs: {x} vs {y}"
            );
        }
    }
}

// --------------------------------------------- detection-driven failover

#[test]
fn detector_driven_kill_matrix_every_model_both_transports() {
    // Primary 0 dies at clock 3 with NO pre-armed promotion: the run
    // report must show the coordinator detected the death and emitted
    // the promotion itself, the workers must have finished the workload
    // through the repoint, and the staleness tripwire must stay zero.
    for consistency in MODELS {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!(
                "detect {} over {}",
                consistency.label(),
                transport.label()
            );
            let mut cfg = base_cfg(transport, consistency, "kill=s0@3");
            cfg.replicas = 1;
            let r = counter_run(cfg, 6);
            assert_counter_landed(&label, &r.table_rows, 6);
            assert_eq!(
                r.staleness_violations, 0,
                "{label}: staleness-violation counter tripped"
            );
            let fo = r
                .failover
                .as_ref()
                .unwrap_or_else(|| panic!("{label}: no detector report"));
            assert!(
                fo.dead.contains(&0),
                "{label}: node 0's death never confirmed (dead={:?})",
                fo.dead
            );
            assert_eq!(
                fo.promotions,
                vec![(0, 2)],
                "{label}: expected partition 0 promoted to its replica"
            );
            assert!(fo.unreplicated.is_empty(), "{label}: lost a partition");
            assert!(
                r.failover_ms.is_some(),
                "{label}: failover window not measured"
            );
        }
    }
}

#[test]
fn detected_promotion_is_bit_identical_to_undisturbed_run() {
    // The kill is pinned to a table clock, the *detection* is wall-clock
    // — yet under deterministic staged replay the promoted replica's
    // sorted (clock, worker) fold is the same fold, so final params
    // match the undisturbed run to the bit over both transports.
    for transport in [TransportSel::Sim, TransportSel::Tcp] {
        let label = format!("detected promote over {}", transport.label());
        let mut plain_cfg = base_cfg(transport, Consistency::Essp { s: 2 }, "");
        plain_cfg.replicas = 1;
        let plain = counter_run(plain_cfg, 6);
        let mut kill_cfg = base_cfg(transport, Consistency::Essp { s: 2 }, "kill=s0@3");
        kill_cfg.replicas = 1;
        let killed = counter_run(kill_cfg, 6);
        assert_bit_identical(&label, &plain.table_rows, &killed.table_rows);
    }
}

#[test]
fn failover_stall_metric_counts_the_window() {
    // Between the primary's death and the client seeing the promotion,
    // in-window GET retries surface as the `failover_stall` client
    // metric rather than as silent latency. (BSP re-pulls every clock,
    // so at least one worker is guaranteed to be in the window.)
    let mut cfg = base_cfg(TransportSel::Sim, Consistency::Bsp, "kill=s0@3");
    cfg.replicas = 1;
    let r = counter_run(cfg, 6);
    let stalls: u64 = r.client_stats.iter().map(|s| s.failover_stalls).sum();
    assert!(
        stalls > 0,
        "no client ever recorded a failover stall across the kill window"
    );
}

// ------------------------------------- double failure -> WAL fallback

#[test]
fn double_failure_falls_back_to_wal_spare_not_dead_replica() {
    // The replica (node 2) dies FIRST, then its primary (node 0): the
    // promotion must not target the dead replica. With a durable WAL and
    // a provisioned spare, the coordinator orders a from-disk rebuild on
    // the spare (node 4), promotes it, and the clients' resend window
    // closes the un-fsynced tail — conserving the counter exactly.
    let dir = tmp_dir("double");
    let mut cfg = base_cfg(TransportSel::Sim, Consistency::Essp { s: 2 }, "kill=s2@2;kill=s0@4");
    cfg.replicas = 1;
    cfg.spare_nodes = 1;
    cfg.resend_window = 4;
    cfg.durability = Some(DurabilityConfig::new(&dir));
    let r = counter_run(cfg, 8);
    assert_counter_landed("double failure", &r.table_rows, 8);
    assert_eq!(r.staleness_violations, 0);
    let fo = r.failover.as_ref().expect("no detector report");
    assert!(
        fo.dead.contains(&2) && fo.dead.contains(&0),
        "both deaths must be confirmed (dead={:?})",
        fo.dead
    );
    assert_eq!(
        fo.promotions,
        vec![(0, 4)],
        "partition 0 must promote onto the spare, never the dead replica"
    );
    assert!(fo.unreplicated.is_empty(), "partition lost despite the spare");
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------------------------------------ re-replication

#[test]
fn re_replication_restores_a_bit_equal_replica() {
    // After promoting partition 0 onto its replica, `re_replicate`
    // catches a fresh spare up from the promoted primary behind an
    // attach fence. By end of run the spare's copy of every row it
    // holds must be bit-equal to the authoritative (promoted) copy, and
    // replica reads must have resumed.
    for transport in [TransportSel::Sim, TransportSel::Tcp] {
        let label = format!("re-replicate over {}", transport.label());
        let mut cfg = base_cfg(transport, Consistency::Bsp, "kill=s0@3");
        cfg.replicas = 1;
        cfg.failover = FailoverConfig {
            re_replicate: true,
            attach_slack: 6,
            ..FailoverConfig::default()
        };
        // clocks must clear the attach fence (observed clock + slack)
        // with room for the cut and a few duplicated commits.
        let clocks = 24;
        let r = counter_run(cfg, clocks);
        assert_counter_landed(&label, &r.table_rows, clocks);
        let fo = r.failover.as_ref().expect("no detector report");
        assert_eq!(fo.promotions, vec![(0, 2)], "{label}");
        assert_eq!(
            fo.attached,
            vec![(0, 4)],
            "{label}: spare never attached as the replacement replica"
        );
        // replica_rows is indexed by node - primaries: node 4 -> index 2.
        let spare_rows = &r.replica_rows[2];
        assert!(
            !spare_rows.is_empty(),
            "{label}: the attached spare holds no rows (cut never landed?)"
        );
        for (k, v) in spare_rows {
            let auth = &r.table_rows[k];
            for (i, (a, b)) in v.iter().zip(auth).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{label}: spare row {k:?} elem {i} diverged from promoted primary"
                );
            }
        }
        assert!(
            r.replica_hits > 0,
            "{label}: replica read fan-out never resumed"
        );
    }
}

// --------------------------------------------------------- chaos smoke

/// Seeded compound fault plan: some subset of {link delay, shard pause,
/// kill, crash} with randomized parameters, always replayable from the
/// printed seed.
fn chaos_plan(seed: u64) -> String {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xC0FF_EE;
    let mut r = move || splitmix64(&mut s);
    let mut parts = vec![format!("seed={seed}")];
    if r() % 2 == 0 {
        parts.push(format!("delay=w*-s*:{}ms", 1 + r() % 3));
    }
    if r() % 2 == 0 {
        parts.push(format!("pause=s1@{}:{}ms", 2 + r() % 3, 1 + r() % 5));
    }
    match r() % 3 {
        0 => parts.push(format!("kill=s0@{}", 2 + r() % 3)),
        1 => parts.push(format!("crash=s{}@{}", r() % 2, 2 + r() % 3)),
        _ => {}
    }
    parts.join(";")
}

#[test]
fn chaos_smoke_every_plan_completes_with_zero_violations() {
    // ~20 randomized compound plans across the model set; CI runs a fast
    // subset by default, ESSPT_CHAOS_FULL=1 runs them all. Any failing
    // seed is printed with its full plan for deterministic replay.
    let full = std::env::var("ESSPT_CHAOS_FULL").is_ok_and(|v| v == "1");
    let seeds: Vec<u64> = if full { (0..20).collect() } else { (0..6).collect() };
    for seed in seeds {
        let plan = chaos_plan(seed);
        let consistency = MODELS[seed as usize % MODELS.len()];
        let dir = tmp_dir(&format!("chaos-{seed}"));
        let mut cfg = base_cfg(TransportSel::Sim, consistency, &plan);
        cfg.replicas = 1; // kills promote a live replica
        cfg.durability = Some(DurabilityConfig::new(&dir)); // crashes recover
        let clocks = 6;
        let r = counter_run(cfg, clocks);
        let ctx = format!(
            "chaos seed {seed} ({}) plan {plan:?} — replay with \
             FaultPlan::parse({plan:?})",
            consistency.label()
        );
        if r.staleness_violations != 0 {
            eprintln!("CHAOS VIOLATION: {ctx}");
        }
        assert_eq!(r.staleness_violations, 0, "{ctx}");
        let v = r.table_rows[&(0, 0)][0] as f64;
        if (v - 0.6 * clocks as f64).abs() >= 1e-2 {
            eprintln!("CHAOS CONSERVATION FAILURE: {ctx}");
        }
        assert_counter_landed(&ctx, &r.table_rows, clocks);
        if let Some(fo) = &r.failover {
            assert!(fo.unreplicated.is_empty(), "{ctx}: partition lost");
        }
        std::fs::remove_dir_all(dir).ok();
    }
}
