//! Integration tests over the full PS stack (cluster + simnet + clients)
//! across consistency models, with delays and stragglers switched on —
//! the paths unit tests cannot reach.

use std::time::Duration;

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use essptable::ps::types::Clock;
use essptable::sim::net::NetConfig;
use essptable::sim::straggler::StragglerModel;

fn lan_cfg(consistency: Consistency, workers: usize) -> ClusterConfig {
    ClusterConfig {
        workers,
        shards: 3,
        consistency,
        net: NetConfig {
            latency: Duration::from_micros(300),
            jitter: Duration::from_micros(200),
            bandwidth: 20e6,
            seed: 9,
        },
        straggler: StragglerModel::RandomUniform { max_factor: 2.5 },
        // The paper's regime: per-clock compute long and uniform relative
        // to comm (see ClusterConfig::virtual_clock). Without this, raw
        // CPU-bound clocks on a timeshared core let workers diffuse to the
        // staleness bound and the ESSP-vs-SSP comparison loses meaning.
        virtual_clock: Some(Duration::from_millis(5)),
        ..Default::default()
    }
}

/// Adder workload: each worker INCs +1 into a set of shared rows each
/// clock; checks conservation under delay + straggle.
fn adder_run(consistency: Consistency, workers: usize, clocks: u64, rows: u64) -> RunReport {
    let mut cluster = Cluster::new(lan_cfg(consistency, workers));
    cluster.add_table(TableSpec::zeros(0, rows, 4));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                for r in 0..rows {
                    let _ = ps.get((0, r));
                    ps.inc((0, r), &[1.0, 0.0, -1.0, 0.5]);
                }
                let _ = w;
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, clocks)
}

fn assert_conserved(report: &RunReport, workers: usize, clocks: u64, rows: u64) {
    let expect = (workers as f32) * (clocks as f32);
    for r in 0..rows {
        let row = &report.table_rows[&(0, r)];
        assert!((row[0] - expect).abs() < 1e-3, "row {r}: {} != {expect}", row[0]);
        assert!((row[2] + expect).abs() < 1e-3);
        assert!((row[3] - 0.5 * expect).abs() < 1e-2);
    }
}

#[test]
fn conservation_under_delay_bsp() {
    let r = adder_run(Consistency::Bsp, 4, 12, 8);
    assert_conserved(&r, 4, 12, 8);
}

#[test]
fn conservation_under_delay_ssp() {
    let r = adder_run(Consistency::Ssp { s: 2 }, 4, 12, 8);
    assert_conserved(&r, 4, 12, 8);
}

#[test]
fn conservation_under_delay_essp() {
    let r = adder_run(Consistency::Essp { s: 2 }, 4, 12, 8);
    assert_conserved(&r, 4, 12, 8);
    assert!(r.shard_stats.iter().any(|s| s.push_waves > 0), "ESSP must push");
}

#[test]
fn conservation_under_delay_async() {
    let r = adder_run(Consistency::Async { refresh_every: 2 }, 4, 12, 8);
    assert_conserved(&r, 4, 12, 8);
}

#[test]
fn conservation_under_delay_vap() {
    let r = adder_run(Consistency::Vap { v0: 50.0 }, 3, 8, 4);
    assert_conserved(&r, 3, 8, 4);
    let (stall, _) = r.vap_stall.expect("vap reports stalls");
    // Stalls may be zero with a loose bound, but the field must exist.
    let _ = stall;
}

#[test]
fn conservation_under_delay_avap() {
    // The composed model (value bound + SSP clock window) lives entirely
    // in the policy layer; conservation and the clock bound must hold
    // under delays and stragglers like every other model.
    let r = adder_run(Consistency::Avap { v0: 50.0, s: 2 }, 3, 8, 4);
    assert_conserved(&r, 3, 8, 4);
    assert!(r.vap_stall.is_some(), "avap reports the value-bound stalls");
    let min = r.staleness.min().unwrap();
    assert!(min >= -3, "avap clock window violated: differential {min}");
}

#[test]
fn staleness_bound_respected_ssp() {
    // The recorded clock differential can never be below -(s+1): the read
    // condition blocks first. And SSP can never read ahead of commits.
    for s in [0i64, 1, 3] {
        let r = adder_run(Consistency::Ssp { s }, 4, 10, 6);
        let min = r.staleness.min().unwrap();
        assert!(min >= -(s + 1), "s={s}: differential {min} below bound");
        assert!(r.staleness.max().unwrap() <= 0);
    }
}

#[test]
fn staleness_bound_respected_essp() {
    for s in [0i64, 2] {
        let r = adder_run(Consistency::Essp { s }, 4, 10, 6);
        let min = r.staleness.min().unwrap();
        assert!(min >= -(s + 1), "s={s}: differential {min} below bound");
    }
}

#[test]
fn essp_staleness_profile_no_worse_than_ssp() {
    // The paper's core Fig-1 claim, at test scale: ESSP's mean clock
    // differential is at least as fresh as SSP's under identical load.
    let ssp = adder_run(Consistency::Ssp { s: 3 }, 4, 20, 6);
    let essp = adder_run(Consistency::Essp { s: 3 }, 4, 20, 6);
    assert!(
        essp.staleness.mean() >= ssp.staleness.mean() - 0.6,
        "essp {} vs ssp {}",
        essp.staleness.mean(),
        ssp.staleness.mean()
    );
}

#[test]
fn vap_stalls_more_with_tighter_bound() {
    let loose = adder_run(Consistency::Vap { v0: 1000.0 }, 3, 8, 4);
    let tight = adder_run(Consistency::Vap { v0: 2.0 }, 3, 8, 4);
    let (stall_loose, _) = loose.vap_stall.unwrap();
    let (stall_tight, _) = tight.vap_stall.unwrap();
    assert!(
        stall_tight >= stall_loose,
        "tight bound must stall at least as much: {stall_tight:?} vs {stall_loose:?}"
    );
}

#[test]
fn replica_fanout_serves_reads_within_staleness_bound() {
    // Replica shards under delays + stragglers: pulls demonstrably fan
    // out to replicas (replica-hit counter), conservation holds, and the
    // recorded clock differential never violates the SSP bound — each
    // replica receives the same FIFO update/clock stream and holds every
    // GET until its OWN table clock meets the floor, so fan-out cannot
    // widen the staleness window.
    let s = 1i64;
    let mut cfg = lan_cfg(Consistency::Ssp { s }, 3);
    cfg.replicas = 1;
    let mut cluster = Cluster::new(cfg);
    cluster.add_table(TableSpec::zeros(0, 8, 4));
    let apps: Vec<Box<dyn PsApp>> = (0..3)
        .map(|_| {
            Box::new(|ps: &mut PsClient, _c: Clock| {
                for r in 0..8u64 {
                    let _ = ps.get((0, r));
                    ps.inc((0, r), &[1.0, 0.0, -1.0, 0.5]);
                }
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 10);
    for r in 0..8u64 {
        let row = &report.table_rows[&(0, r)];
        assert!((row[0] - 30.0).abs() < 1e-3, "row {r}: {}", row[0]);
    }
    assert!(
        report.replica_hits > 0,
        "no pull was ever served by a replica"
    );
    let min = report.staleness.min().unwrap();
    assert!(
        min >= -(s + 1),
        "replica-served reads violated the SSP bound: differential {min}"
    );
    assert!(report.staleness.max().unwrap() <= 0);
}

#[test]
fn cache_eviction_does_not_break_consistency() {
    // Cache capacity below the working set: rows get evicted and
    // re-pulled; conservation and the staleness bound must still hold.
    let mut cfg = lan_cfg(Consistency::Ssp { s: 1 }, 3);
    cfg.cache_capacity = 3; // working set is 8 rows
    let mut cluster = Cluster::new(cfg);
    cluster.add_table(TableSpec::zeros(0, 8, 4));
    let apps: Vec<Box<dyn PsApp>> = (0..3)
        .map(|_| {
            Box::new(|ps: &mut PsClient, _c: Clock| {
                for r in 0..8u64 {
                    let _ = ps.get((0, r));
                    ps.inc((0, r), &[1.0, 0.0, 0.0, 0.0]);
                }
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    let report = cluster.run(apps, 10);
    for r in 0..8u64 {
        assert!((report.table_rows[&(0, r)][0] - 30.0).abs() < 1e-3);
    }
    assert!(report.staleness.min().unwrap() >= -2);
}

#[test]
fn read_my_writes_visible_within_clock() {
    let mut cluster = Cluster::new(ClusterConfig {
        workers: 1,
        shards: 1,
        consistency: Consistency::Bsp,
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 1, 1));
    let apps: Vec<Box<dyn PsApp>> = vec![Box::new(|ps: &mut PsClient, c: Clock| {
        let before = ps.get((0, 0))[0];
        ps.inc((0, 0), &[1.0]);
        let after = ps.get((0, 0))[0];
        assert!(
            (after - before - 1.0).abs() < 1e-6,
            "clock {c}: pending inc not visible ({before} -> {after})"
        );
        None
    })];
    let _ = cluster.run(apps, 5);
}

#[test]
fn deterministic_final_state_bsp() {
    // BSP with a deterministic app: the final table must be identical
    // across runs (clock barriers serialize every update set).
    let run = || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers: 3,
            shards: 2,
            consistency: Consistency::Bsp,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 4, 2));
        let apps: Vec<Box<dyn PsApp>> = (0..3)
            .map(|w| {
                Box::new(move |ps: &mut PsClient, c: Clock| {
                    let v = ps.get((0, w as u64))[0];
                    ps.inc((0, w as u64), &[v * 0.5 + (c as f32), 1.0]);
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        cluster.run(apps, 6).table_rows
    };
    let a = run();
    let b = run();
    for r in 0..4u64 {
        assert_eq!(a[&(0, r)], b[&(0, r)], "row {r} differs across BSP runs");
    }
}

#[test]
fn net_stats_populated() {
    let r = adder_run(Consistency::Essp { s: 1 }, 3, 6, 4);
    assert!(r.net_messages > 0);
    assert!(r.net_bytes > 0);
    assert!(r.wall > Duration::ZERO);
    assert_eq!(r.timelines.len(), 3);
    assert_eq!(r.client_stats.len(), 3);
}
