//! Crash-tolerance integration: the WAL + checkpoint generation plane,
//! replica promotion, and the seeded fault injector, proving the
//! acceptance bar end-to-end —
//!
//!   * **crash-recover equivalence**: for every consistency model, a
//!     shard losing its volatile state mid-run and recovering from
//!     checkpoint + WAL tail yields final params bit-identical to the
//!     undisturbed deterministic run, over both sim and tcp;
//!   * **kill-promotion equivalence**: killing a primary mid-run (its
//!     replica is promoted via a fence-free placement delta) is likewise
//!     bit-invisible in the final params, for every model over both
//!     transports;
//!   * staleness bounds survive the faults: the recorded clock
//!     differential never exceeds the model's window in any faulted run,
//!     and the first-class violation counter stays zero;
//!   * compaction rolls generations forward and purges stale pairs.
//!
//! The workload is the repo's order-sensitive fractional counter (dense
//! + sparse INCs whose float fold depends on summation order), the
//! established bit-determinism probe.

use std::collections::HashMap;
use std::path::PathBuf;

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::durability::{self, wal, DurabilityConfig, FsyncPolicy};
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, RunReport, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::sim::fault::FaultPlan;
use essptable::transport::TransportSel;

const MODELS: [Consistency; 6] = [
    Consistency::Bsp,
    Consistency::Ssp { s: 2 },
    Consistency::Essp { s: 2 },
    Consistency::Async { refresh_every: 1 },
    Consistency::Vap { v0: 100.0 },
    Consistency::Avap { v0: 100.0, s: 2 },
];

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("esspt-durint-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The order-sensitive fractional counter over 2 shards: worker `w` adds
/// 0.1*(w+1) to a shared dense row and two sparse indices of a wide row
/// every clock for 6 clocks.
fn counter_run(
    transport: TransportSel,
    consistency: Consistency,
    replicas: usize,
    faults: &str,
    durability: Option<DurabilityConfig>,
) -> RunReport {
    let workers = 3;
    let mut cluster = Cluster::new(ClusterConfig {
        workers,
        shards: 2,
        replicas,
        consistency,
        transport,
        deterministic: true,
        durability,
        faults: FaultPlan::parse(faults).unwrap(),
        ..Default::default()
    });
    cluster.add_table(TableSpec::zeros(0, 4, 1));
    cluster.add_table(TableSpec::zeros(1, 2, 64));
    let apps: Vec<Box<dyn PsApp>> = (0..workers)
        .map(|w| {
            Box::new(move |ps: &mut PsClient, _c: Clock| {
                let _ = ps.get((0, 0));
                ps.inc((0, 0), &[0.1 * (w + 1) as f32]);
                let _ = ps.get((1, 0));
                ps.inc_sparse((1, 0), &[(w, 0.1 * (w + 1) as f32), (17 + w, 0.01)]);
                None
            }) as Box<dyn PsApp>
        })
        .collect();
    cluster.run(apps, 6)
}

fn assert_bit_identical(ctx: &str, a: &HashMap<Key, Vec<f32>>, b: &HashMap<Key, Vec<f32>>) {
    assert_eq!(a.len(), b.len(), "{ctx}: row sets differ");
    for (k, va) in a {
        let vb = b
            .get(k)
            .unwrap_or_else(|| panic!("{ctx}: row {k:?} missing"));
        assert_eq!(va.len(), vb.len(), "{ctx}: row {k:?} length differs");
        for (i, (x, y)) in va.iter().zip(vb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: row {k:?} elem {i} differs: {x} vs {y}"
            );
        }
    }
}

/// The faulted run's staleness profile must still respect the model's
/// promised window: a crash-recover or promotion is not allowed to leak
/// a read staler than `s` (differential below -(s+1)).
fn assert_bound_survives(ctx: &str, report: &RunReport, consistency: Consistency) {
    // The first-class tripwire (ps::server § Observability): no faulted
    // run may admit a single read below its certified clock bound. Zero
    // for the unbounded models too — they never certify a bound at all.
    assert_eq!(
        report.staleness_violations, 0,
        "{ctx}: staleness-violation counter tripped"
    );
    let s = match consistency {
        Consistency::Bsp => 0,
        Consistency::Ssp { s } | Consistency::Essp { s } | Consistency::Avap { s, .. } => s,
        // Async and plain VAP promise no clock window.
        _ => return,
    };
    if let Some(min) = report.staleness.min() {
        assert!(
            min >= -(s + 1),
            "{ctx}: staleness differential {min} violates the s={s} bound"
        );
    }
}

fn assert_counter_landed(ctx: &str, rows: &HashMap<Key, Vec<f32>>) {
    // 3 workers x 6 clocks x 0.1*(w+1): ~3.6 total in the dense row —
    // the faulted run did the whole workload, nothing was lost or
    // double-applied through recovery.
    let v = rows[&(0, 0)][0];
    assert!((v - 3.6).abs() < 1e-3, "{ctx}: expected ~3.6 total, got {v}");
}

// ------------------------------------------------- crash + WAL recovery

#[test]
fn crash_recover_matrix_every_model_bit_identical() {
    for consistency in MODELS {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!(
                "crash {} over {}",
                consistency.label(),
                transport.label()
            );
            let dir = tmp_dir(&format!(
                "crash-{}-{}",
                consistency.label(),
                transport.label()
            ));
            let plain = counter_run(transport, consistency, 0, "", None);
            let crashed = counter_run(
                transport,
                consistency,
                0,
                "crash=s0@3",
                Some(DurabilityConfig::new(&dir)),
            );
            assert_bit_identical(&label, &plain.table_rows, &crashed.table_rows);
            assert_counter_landed(&label, &crashed.table_rows);
            assert_bound_survives(&label, &crashed, consistency);
            std::fs::remove_dir_all(dir).ok();
        }
    }
}

#[test]
fn enabling_the_wal_does_not_change_results() {
    // Durability must be observationally free: a run with the WAL on
    // (no faults) is bit-identical to the same run without it.
    let dir = tmp_dir("wal-noop");
    let plain = counter_run(TransportSel::Sim, Consistency::Essp { s: 2 }, 0, "", None);
    let logged = counter_run(
        TransportSel::Sim,
        Consistency::Essp { s: 2 },
        0,
        "",
        Some(DurabilityConfig::new(&dir)),
    );
    assert_bit_identical("wal on vs off", &plain.table_rows, &logged.table_rows);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn pause_and_slow_fsync_are_bit_invisible() {
    // Gray failures: a mid-run shard stall plus fault-injected slow
    // fsyncs change timing, never results, under deterministic replay.
    let dir = tmp_dir("gray");
    let plain = counter_run(TransportSel::Sim, Consistency::Ssp { s: 1 }, 0, "", None);
    let faulted = counter_run(
        TransportSel::Sim,
        Consistency::Ssp { s: 1 },
        0,
        "pause=s0@2:5ms;fsync-stall=1ms",
        Some(DurabilityConfig::new(&dir)),
    );
    assert_bit_identical("pause + fsync-stall", &plain.table_rows, &faulted.table_rows);
    assert_bound_survives("pause + fsync-stall", &faulted, Consistency::Ssp { s: 1 });
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn injected_link_delay_is_bit_invisible_under_determinism() {
    // A seeded 1ms delay on every worker->shard link reshuffles arrival
    // timing but respects per-link FIFO; deterministic staged replay must
    // absorb it bit-exactly over both data planes.
    for transport in [TransportSel::Sim, TransportSel::Tcp] {
        let label = format!("link delay over {}", transport.label());
        let plain = counter_run(transport, Consistency::Essp { s: 2 }, 0, "", None);
        let delayed = counter_run(
            transport,
            Consistency::Essp { s: 2 },
            0,
            "seed=11;delay=w*-s*:1ms",
            None,
        );
        assert_bit_identical(&label, &plain.table_rows, &delayed.table_rows);
    }
}

// ----------------------------------------------------- kill + promotion

#[test]
fn kill_promotion_matrix_every_model_bit_identical() {
    // The headline guarantee: primary 0 dies at clock 3 and its replica
    // is promoted by the fence-free placement delta it sent as its dying
    // act. Replicas have been fed the identical per-worker FIFO
    // update/clock stream all along, so the promoted copy's sorted
    // (clock, worker) fold is the same fold — final params match the
    // unkilled run to the bit, for every model, over both transports.
    for consistency in MODELS {
        for transport in [TransportSel::Sim, TransportSel::Tcp] {
            let label = format!(
                "kill {} over {}",
                consistency.label(),
                transport.label()
            );
            let plain = counter_run(transport, consistency, 1, "", None);
            let killed = counter_run(transport, consistency, 1, "kill=s0@3", None);
            assert_bit_identical(&label, &plain.table_rows, &killed.table_rows);
            assert_counter_landed(&label, &killed.table_rows);
            assert_bound_survives(&label, &killed, consistency);
        }
    }
}

#[test]
fn kill_with_wal_enabled_still_promotes_cleanly() {
    // Both recovery planes at once: every node logs durably AND primary 0
    // is killed. The promoted replica's durable log must not conflict
    // with the dead primary's files (paths embed the shard id).
    let dir = tmp_dir("kill-wal");
    let plain = counter_run(TransportSel::Sim, Consistency::Ssp { s: 2 }, 1, "", None);
    let killed = counter_run(
        TransportSel::Sim,
        Consistency::Ssp { s: 2 },
        1,
        "kill=s0@3",
        Some(DurabilityConfig::new(&dir)),
    );
    assert_bit_identical("kill + wal", &plain.table_rows, &killed.table_rows);
    std::fs::remove_dir_all(dir).ok();
}

// ----------------------------------------------------------- compaction

#[test]
fn compaction_rolls_generations_and_purges_old_pairs() {
    let dir = tmp_dir("compact");
    let mut cfg = DurabilityConfig::new(&dir);
    cfg.fsync = FsyncPolicy::Off;
    cfg.compact_every = 2;
    let r = counter_run(TransportSel::Sim, Consistency::Essp { s: 1 }, 0, "", Some(cfg));
    assert_counter_landed("compaction run", &r.table_rows);
    for shard in 0..2 {
        let g = durability::latest_generation(&dir, shard)
            .unwrap_or_else(|| panic!("shard {shard} left no durable generation"));
        assert!(
            g >= 1,
            "shard {shard}: 6 commits at compact_every=2 never rolled the generation"
        );
        // Everything below the live generation is purged.
        for old in 0..g {
            assert!(
                !durability::ckpt_path(&dir, shard, old).exists(),
                "shard {shard}: stale checkpoint gen {old} survived compaction"
            );
            assert!(
                !durability::wal_path(&dir, shard, old).exists(),
                "shard {shard}: stale WAL gen {old} survived compaction"
            );
        }
        // The surviving pair is complete and cleanly readable: the WAL
        // parses strictly (no torn tail on an orderly shutdown) and
        // carries the generation it claims.
        let read = wal::replay_strict(&durability::wal_path(&dir, shard, g))
            .unwrap_or_else(|e| panic!("shard {shard} gen {g} WAL unreadable: {e:#}"));
        assert_eq!(read.header.generation, g);
        assert_eq!(read.header.shard, shard as u32);
    }
    std::fs::remove_dir_all(dir).ok();
}
