//! Cross-model application integration: the paper's qualitative claims at
//! test scale, on the native compute path (fast; the XLA path is covered
//! by integration_runtime.rs).

use essptable::apps::lda::gibbs::run_lda;
use essptable::apps::lda::LdaConfig;
use essptable::apps::logreg::{run_logreg, LogRegConfig, W_TABLE};
use essptable::apps::mf::train::{final_sq_loss, run_mf, MfBackend};
use essptable::apps::mf::MfConfig;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::ClusterConfig;

fn mf_cfg() -> MfConfig {
    MfConfig {
        rows: 128,
        cols: 128,
        rank: 8,
        true_rank: 4,
        nnz_per_row: 24,
        noise: 0.01,
        gamma: 0.05,
        lambda: 0.01,
        minibatch: 1.0,
        ..Default::default()
    }
}

fn cluster(consistency: Consistency) -> ClusterConfig {
    ClusterConfig {
        workers: 2,
        shards: 2,
        consistency,
        ..Default::default()
    }
}

#[test]
fn mf_all_models_converge_to_similar_loss() {
    // Error tolerance claim: every bounded model reaches a comparable
    // optimum; async is close too at this scale.
    let mut finals = Vec::new();
    for c in [
        Consistency::Bsp,
        Consistency::Ssp { s: 2 },
        Consistency::Essp { s: 2 },
        Consistency::Async { refresh_every: 1 },
    ] {
        let (report, data) = run_mf(cluster(c), mf_cfg(), 40, MfBackend::Native);
        let f = final_sq_loss(&report, &data);
        assert!(f.is_finite(), "{c}: diverged");
        finals.push((c.label(), f));
    }
    let best = finals.iter().map(|(_, f)| *f).fold(f64::INFINITY, f64::min);
    for (label, f) in &finals {
        assert!(
            *f < 12.0 * best.max(1.0),
            "{label} final {f} too far above best {best} ({finals:?})"
        );
    }
}

#[test]
fn mf_staleness_speeds_up_wall_clock() {
    // Paper Fig 2: staleness buys wall-clock speed (fewer blocking waits)
    // at comparable per-iteration quality. Compare BSP vs ESSP:2 under a
    // delayed network.
    use essptable::sim::net::NetConfig;
    use std::time::Duration;
    let run = |c: Consistency| {
        let cfg = ClusterConfig {
            workers: 4,
            shards: 2,
            consistency: c,
            net: NetConfig {
                latency: Duration::from_millis(2),
                jitter: Duration::from_micros(500),
                bandwidth: 20e6,
                seed: 3,
            },
            ..Default::default()
        };
        let (report, data) = run_mf(cfg, mf_cfg(), 20, MfBackend::Native);
        (report.wall, final_sq_loss(&report, &data))
    };
    let (wall_bsp, loss_bsp) = run(Consistency::Bsp);
    let (wall_essp, loss_essp) = run(Consistency::Essp { s: 3 });
    assert!(
        wall_essp < wall_bsp,
        "ESSP should beat BSP wall-clock: {wall_essp:?} vs {wall_bsp:?}"
    );
    assert!(loss_essp.is_finite() && loss_bsp.is_finite());
    assert!(loss_essp < 3.0 * loss_bsp.max(1.0), "{loss_essp} vs {loss_bsp}");
}

#[test]
fn lda_loglik_ascends_all_models() {
    let lda = LdaConfig {
        vocab: 60,
        topics: 4,
        docs: 40,
        doc_len: 30,
        minibatch: 1.0,
        ..Default::default()
    };
    for c in [Consistency::Bsp, Consistency::Ssp { s: 2 }, Consistency::Essp { s: 2 }] {
        let (report, _) = run_lda(cluster(c), lda.clone(), 10);
        let series = report.convergence.summed();
        let early = series[1].value;
        let late = series.last().unwrap().value;
        assert!(late > early, "{c}: log-lik did not ascend ({early} -> {late})");
    }
}

#[test]
fn lda_token_mass_conserved_under_stale_reads() {
    let lda = LdaConfig {
        vocab: 80,
        topics: 5,
        docs: 60,
        doc_len: 25,
        minibatch: 0.5,
        ..Default::default()
    };
    let (report, corpus) = run_lda(cluster(Consistency::Essp { s: 3 }), lda, 12);
    let tt: f64 = report.table_rows[&(essptable::apps::lda::TOPIC_TABLE, 0)]
        .iter()
        .map(|&x| x as f64)
        .sum();
    assert!((tt - corpus.total_tokens() as f64).abs() < 1e-3);
}

#[test]
fn logreg_consistent_across_models() {
    for c in [Consistency::Bsp, Consistency::Essp { s: 2 }] {
        let (report, data) = run_logreg(cluster(c), LogRegConfig::default(), 40);
        let w = &report.table_rows[&(W_TABLE, 0)];
        assert!(data.accuracy(w) > 0.85, "{c}: accuracy too low");
    }
}

#[test]
fn robustness_shape_ssp_worse_at_high_staleness_large_step() {
    // §Robustness: with an aggressive step size and *actual* staleness
    // (stragglers + network delay let SSP reads drift to the bound, while
    // ESSP's eager pushes keep empirical staleness low), high staleness
    // destabilizes SSP far more than ESSP. Needs the LAN profile: on an
    // instant network the bound is never exercised.
    use essptable::sim::net::NetConfig;
    use essptable::sim::straggler::StragglerModel;
    use std::time::Duration;
    let aggressive = MfConfig {
        rows: 256,
        cols: 256,
        gamma: 0.15,
        ..mf_cfg()
    };
    let run = |c: Consistency| {
        let (report, data) = run_mf(
            ClusterConfig {
                workers: 8,
                shards: 2,
                consistency: c,
                net: NetConfig::lan(42),
                straggler: StragglerModel::RandomUniform { max_factor: 3.0 },
                virtual_clock: Some(Duration::from_millis(10)),
                ..Default::default()
            },
            aggressive.clone(),
            40,
            MfBackend::Native,
        );
        final_sq_loss(&report, &data)
    };
    let ssp = run(Consistency::Ssp { s: 10 });
    let essp = run(Consistency::Essp { s: 10 });
    assert!(
        essp.is_finite(),
        "ESSP must stay stable at high staleness (got {essp})"
    );
    assert!(
        !ssp.is_finite() || essp < ssp,
        "ESSP should end lower than SSP at s=10, large step: essp {essp} vs ssp {ssp}"
    );
}
