//! Property-based tests over PS invariants (DESIGN.md §7).
//!
//! The offline vendor set has no `proptest`, so this uses a small seeded
//! generator harness: each property runs across many random cases derived
//! from a fixed master seed (reproducible; failures print the case seed).

use essptable::ps::cache::RowCache;
use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::placement::{plan_shards, PlacementDelta, PlacementMap};
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, TableSpec};
use essptable::ps::types::{Clock, Key};
use essptable::ps::update::UpdateMap;
use essptable::ps::vclock::MinClock;
use essptable::sim::net::NetConfig;
use essptable::sim::straggler::StragglerModel;
use essptable::util::json::Json;
use essptable::util::rng::Rng;

/// Run `prop` on `cases` seeded cases.
fn for_cases(cases: u64, mut prop: impl FnMut(u64, &mut Rng)) {
    for case in 0..cases {
        let mut rng = Rng::with_stream(0xC0FFEE, case);
        prop(case, &mut rng);
    }
}

#[test]
fn prop_coalescing_lossless() {
    // Sum of drained routed batches == elementwise sum of raw INCs,
    // regardless of inc order, sparsity mix, and shard count.
    for_cases(50, |case, rng| {
        let rows = 1 + rng.usize_below(20) as u64;
        let len = 1 + rng.usize_below(16);
        let shards = 1 + rng.usize_below(5);
        let mut m = UpdateMap::new();
        let mut expect = vec![vec![0.0f32; len]; rows as usize];
        for _ in 0..rng.usize_below(500) {
            let r = rng.below(rows);
            if rng.f64() < 0.5 {
                let delta: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                for (e, d) in expect[r as usize].iter_mut().zip(&delta) {
                    *e += d;
                }
                m.inc((0, r), &delta);
            } else {
                let idx = rng.usize_below(len);
                let v = rng.normal_f32();
                expect[r as usize][idx] += v;
                m.inc_sparse((0, r), len, &[(idx, v)]);
            }
        }
        let placement = PlacementMap::flat(shards);
        let batches = m.drain_routed(shards, |k| placement.shard_of(k));
        let mut got = vec![vec![0.0f32; len]; rows as usize];
        for (shard, batch) in batches.iter().enumerate() {
            for (key, delta) in batch {
                assert_eq!(placement.shard_of(key), shard, "case {case}: misrouted");
                delta.add_into(&mut got[key.1 as usize]);
            }
        }
        for (r, (g, e)) in got.iter().zip(&expect).enumerate() {
            for (a, b) in g.iter().zip(e) {
                assert!((a - b).abs() < 1e-3, "case {case} row {r}: {a} vs {b}");
            }
        }
    });
}

#[test]
fn prop_dense_sparse_coalescing_equivalent() {
    // The same random INC stream fed once as sparse pairs and once as the
    // equivalent dense vectors coalesces to bit-identical applied rows —
    // including when the sparse accumulator crosses the densify threshold
    // mid-stream. (Both paths perform the same per-index float additions
    // in the same order; only the storage representation differs.)
    let mut crossed = 0u32;
    for_cases(60, |case, rng| {
        let len = 2 + rng.usize_below(30);
        let rows = 1 + rng.usize_below(4) as u64;
        let mut sparse_m = UpdateMap::new();
        let mut dense_m = UpdateMap::new();
        for _ in 0..rng.usize_below(120) {
            let r = rng.below(rows);
            // Distinct indices per call (as real INC streams have): a
            // duplicate would pre-sum on the dense side but fold twice on
            // the sparse side — same value, different rounding order.
            let nnz = 1 + rng.usize_below(3);
            let mut idxs: Vec<usize> = (0..len).collect();
            rng.shuffle(&mut idxs);
            let pairs: Vec<(usize, f32)> = idxs
                .into_iter()
                .take(nnz)
                .map(|i| (i, rng.normal_f32()))
                .collect();
            sparse_m.inc_sparse((0, r), len, &pairs);
            let mut dvec = vec![0.0f32; len];
            for &(i, v) in &pairs {
                dvec[i] = v;
            }
            dense_m.inc((0, r), &dvec);
        }
        for key in dense_m.keys() {
            let s = sparse_m.pending(&key).unwrap();
            if !s.is_sparse() {
                crossed += 1;
            }
            let s = s.clone().to_dense();
            let d = dense_m.pending(&key).unwrap().clone().to_dense();
            assert_eq!(d.len(), s.len(), "case {case} key {key:?}");
            for (i, (x, y)) in d.iter().zip(&s).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "case {case} key {key:?} elem {i}: dense {x} vs sparse {y}"
                );
            }
        }
    });
    assert!(
        crossed > 0,
        "no case ever crossed the densify threshold: property under-tested"
    );
}

#[test]
fn prop_min_clock_is_min() {
    for_cases(50, |case, rng| {
        let workers = 1 + rng.usize_below(8);
        let mut mc = MinClock::new(workers);
        let mut committed = vec![-1i64; workers];
        for _ in 0..200 {
            let w = rng.usize_below(workers);
            let c = committed[w] + 1 + rng.below(3) as i64;
            committed[w] = c;
            mc.commit(w, c);
            assert_eq!(
                mc.min(),
                *committed.iter().min().unwrap(),
                "case {case}: min mismatch"
            );
        }
    });
}

#[test]
fn prop_lru_never_exceeds_capacity_and_keeps_hot() {
    for_cases(40, |case, rng| {
        let cap = 1 + rng.usize_below(8);
        let mut cache = RowCache::new(cap);
        let hot: Key = (0, 999);
        cache.insert(hot, vec![1.0], 0, 0, 0);
        for i in 0..rng.usize_below(200) {
            let _ = cache.get(&hot); // keep hot row warm
            cache.insert((0, i as u64), vec![0.0], 0, 0, 0);
            assert!(cache.len() <= cap, "case {case}: over capacity");
        }
        if cap > 1 {
            assert!(
                cache.peek(&hot).is_some(),
                "case {case}: hot row evicted despite constant use"
            );
        }
    });
}

#[test]
fn prop_staleness_bound_never_violated() {
    // Random consistency, worker count, straggling, jitter: the recorded
    // clock differential is always within [-(s+1), 0].
    for_cases(8, |case, rng| {
        let s = rng.below(4) as i64;
        let consistency = if rng.f64() < 0.5 {
            Consistency::Ssp { s }
        } else {
            Consistency::Essp { s }
        };
        let workers = 2 + rng.usize_below(3);
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 1 + rng.usize_below(3),
            consistency,
            net: NetConfig {
                latency: std::time::Duration::from_micros(rng.below(500)),
                jitter: std::time::Duration::from_micros(rng.below(300)),
                bandwidth: 10e6,
                seed: case,
            },
            straggler: StragglerModel::RandomUniform { max_factor: 2.0 },
            seed: case,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 6, 2));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|_| {
                Box::new(|ps: &mut PsClient, _c: Clock| {
                    for r in 0..6u64 {
                        let _ = ps.get((0, r));
                        ps.inc((0, r), &[1.0, -1.0]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let report = cluster.run(apps, 8);
        let min = report.staleness.min().unwrap();
        let max = report.staleness.max().unwrap();
        assert!(
            min >= -(s + 1),
            "case {case} ({consistency}): differential {min} < -(s+1)"
        );
        assert!(max <= 0, "case {case}: differential {max} > 0");
        // Conservation, while we're here.
        for r in 0..6u64 {
            let v = report.table_rows[&(0, r)][0];
            assert!((v - (workers * 8) as f32).abs() < 1e-3, "case {case}: {v}");
        }
    });
}

#[test]
fn prop_consistency_parse_label_roundtrip() {
    // Every model — including the policy-layer-only avap — round-trips
    // through its label exactly (f32 Display in Rust prints the shortest
    // representation that re-parses to the same bits, so v0 survives).
    for_cases(300, |case, rng| {
        let s = rng.below(1000) as i64;
        let refresh = 1 + rng.below(100) as i64;
        let v0 = (rng_f32(rng) * 100.0).abs().max(1e-6);
        let m = match case % 6 {
            0 => Consistency::Bsp,
            1 => Consistency::Ssp { s },
            2 => Consistency::Essp { s },
            3 => Consistency::Async {
                refresh_every: refresh,
            },
            4 => Consistency::Vap { v0 },
            _ => Consistency::Avap { v0, s },
        };
        let label = m.label();
        let back = Consistency::parse(&label)
            .unwrap_or_else(|e| panic!("case {case}: {label:?} failed to re-parse: {e}"));
        assert_eq!(back, m, "case {case}: {label:?} round-tripped to {back:?}");
        assert_eq!(back.label(), label, "case {case}: label not idempotent");
    });
    // Malformed strings are rejected, never mis-parsed.
    for bad in [
        "",
        "bsp:0",
        "ssp",
        "ssp:",
        "ssp:-1",
        "ssp:1:2",
        "essp",
        "essp:x",
        "async:0",
        "async:-3",
        "async:1.5",
        "vap",
        "vap:",
        "vap:0",
        "vap:-0.5",
        "vap:nan",
        "vap:inf",
        "avap",
        "avap:0.5",
        "avap:0.5:",
        "avap:0.5:-1",
        "avap::2",
        "avap:0.5:2:9",
        "wild:1",
        "BSP",
    ] {
        assert!(
            Consistency::parse(bad).is_err(),
            "{bad:?} must be rejected"
        );
    }
}

/// Uniform-ish f32 in [-1, 1) from the shared test rng.
fn rng_f32(rng: &mut Rng) -> f32 {
    (rng.f64() * 2.0 - 1.0) as f32
}

#[test]
fn prop_placement_agrees_across_instances() {
    // Zero-coordination property within an epoch: two independently
    // constructed maps (a client's and a shard's) route identically.
    for_cases(30, |case, rng| {
        let shards = 1 + rng.usize_below(16);
        let a = PlacementMap::flat(shards);
        let b = PlacementMap::flat(shards);
        for _ in 0..100 {
            let key: Key = (rng.next_u32(), rng.next_u64());
            assert_eq!(a.shard_of(&key), b.shard_of(&key), "case {case}");
        }
    });
}

#[test]
fn prop_placement_delta_is_conservative() {
    // Any epoch-N -> epoch-N+1 delta changes a key's owner ONLY if the
    // delta names it (an explicit move, or a hash re-home onto the grown
    // active set) — `PlacementDelta::affects` is a sound over-
    // approximation of "owner changed".
    for_cases(60, |case, rng| {
        let primaries = 2 + rng.usize_below(6);
        let active = 1 + rng.usize_below(primaries);
        let replicas = rng.usize_below(3);
        let mut map = PlacementMap::new(primaries, active, replicas);
        // A random prior epoch of moves, so conservativeness is tested
        // against maps with override state, not just fresh hash maps.
        let pre_moves: Vec<(Key, u32)> = (0..rng.usize_below(5))
            .map(|_| {
                (
                    (rng.next_u32() % 4, rng.below(64)),
                    rng.usize_below(primaries) as u32,
                )
            })
            .collect();
        map.apply(&PlacementDelta {
            epoch: 1,
            at_clock: 1,
            grow_active: None,
            promote: None,
            attach: None,
            dead: vec![],
            moves: pre_moves,
        });
        let before = map.clone();
        let grow_active = if rng.f64() < 0.6 {
            let max_mult = primaries / before.active();
            let mult = 1 + rng.usize_below(max_mult);
            Some((before.active() * mult) as u32)
        } else {
            None
        };
        let moves: Vec<(Key, u32)> = (0..rng.usize_below(4))
            .map(|_| {
                (
                    (rng.next_u32() % 4, rng.below(64)),
                    rng.usize_below(primaries) as u32,
                )
            })
            .collect();
        let delta = PlacementDelta {
            epoch: 2,
            at_clock: 5,
            grow_active,
            promote: None,
            attach: None,
            dead: vec![],
            moves,
        };
        let mut after = before.clone();
        after.apply(&delta);
        assert_eq!(after.epoch(), 2);
        for _ in 0..300 {
            let key: Key = (rng.next_u32() % 4, rng.below(64));
            if before.shard_of(&key) != after.shard_of(&key) {
                assert!(
                    delta.affects(&key, &before),
                    "case {case}: owner of {key:?} changed without the delta \
                     naming it ({} -> {})",
                    before.shard_of(&key),
                    after.shard_of(&key)
                );
            }
        }
    });
}

#[test]
fn prop_post_migration_routing_agrees_between_client_and_shards() {
    // Shards never hold the map; they hold forward tables derived from
    // the handoff plan. For any key in the universe, the shard a
    // pre-switch client would hit either still owns it or forwards in
    // ONE hop to exactly the owner the post-switch map names — on the
    // primary and on every replica chain.
    for_cases(40, |case, rng| {
        let primaries = 2 + rng.usize_below(5);
        let active = 1 + rng.usize_below(primaries);
        let replicas = rng.usize_below(3);
        let before = PlacementMap::new(primaries, active, replicas);
        let keys: Vec<Key> = (0..64u64).map(|i| (rng.next_u32() % 3, i)).collect();
        let moves: Vec<(Key, u32)> = (0..rng.usize_below(4))
            .map(|_| {
                (
                    keys[rng.usize_below(keys.len())],
                    rng.usize_below(primaries) as u32,
                )
            })
            .collect();
        let mult = 1 + rng.usize_below(primaries / active);
        let delta = PlacementDelta {
            epoch: 1,
            at_clock: 3,
            grow_active: Some((active * mult) as u32),
            promote: None,
            attach: None,
            dead: vec![],
            moves,
        };
        let plans = plan_shards(&before, &delta, keys.iter().copied());
        let mut after = before.clone();
        after.apply(&delta);
        let mut fwd: Vec<std::collections::HashMap<Key, usize>> =
            vec![std::collections::HashMap::new(); before.total_shards()];
        for (id, plan) in plans.iter().enumerate() {
            for &(k, d) in &plan.outgoing {
                fwd[id].insert(k, d as usize);
            }
        }
        for &key in &keys {
            let old = before.shard_of(&key);
            let new = after.shard_of(&key);
            let landed = *fwd[old].get(&key).unwrap_or(&old);
            assert_eq!(
                landed, new,
                "case {case}: key {key:?} routed {old} -> {landed}, map says {new}"
            );
            assert!(
                !fwd[landed].contains_key(&key),
                "case {case}: forward chains must be one hop"
            );
            for r in 0..replicas {
                let old_r = before.replica_of(old, r);
                let landed_r = *fwd[old_r].get(&key).unwrap_or(&old_r);
                assert_eq!(
                    landed_r,
                    after.replica_of(new, r),
                    "case {case}: replica chain {r} diverged for {key:?}"
                );
            }
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.below(1000))),
            4 => Json::Arr((0..rng.usize_below(4)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for_cases(100, |case, rng| {
        let v = gen(rng, 0);
        for indent in [0, 2] {
            let text = v.to_string_pretty(indent);
            let re = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
            assert_eq!(v, re, "case {case} (indent {indent})");
        }
    });
}

#[test]
fn prop_rng_below_uniformity() {
    for_cases(10, |case, rng| {
        let n = 2 + rng.below(20);
        let mut counts = vec![0usize; n as usize];
        let draws = 5000;
        for _ in 0..draws {
            counts[rng.below(n) as usize] += 1;
        }
        let expect = draws as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > 0.4 * expect && (c as f64) < 2.0 * expect,
                "case {case}: bucket {i} has {c} (expect ~{expect})"
            );
        }
    });
}
