//! PS microbenchmarks — §ESSPTable system claims:
//!   * update coalescing reduces message count and amortizes INC cost,
//!   * server-push batching beats per-request pull on refresh traffic,
//!   * the GET/INC hot path is allocation-light and fast.
//!
//! Run with `cargo bench --bench ps_throughput` (or `scripts/bench.sh`).
//!
//! Besides printing, results are written to `BENCH_ps_throughput.json`
//! (path overridable via `ESSPTABLE_BENCH_JSON`). The writer preserves the
//! previous run as `baseline` the first time it sees one, so running the
//! bench before and after a perf change records both numbers plus the
//! speedup — the perf ratchet the ROADMAP asks every PR to feed.

use std::collections::BTreeMap;

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::durability::{DurabilityConfig, FsyncPolicy};
use essptable::ps::failover::FailoverConfig;
use essptable::ps::server::{Cluster, ClusterConfig, MigrationSpec, PsApp, TableSpec};
use essptable::ps::types::Clock;
use essptable::ps::update::UpdateMap;
use essptable::sim::fault::FaultPlan;
use essptable::sim::net::NetConfig;
use essptable::transport::TransportSel;
use essptable::util::benchkit::bench;
use essptable::util::json::Json;

/// One recorded measurement: (stable name, mean seconds, items/s).
type Entry = (String, f64, f64);

/// Raw coalescing throughput: INCs folded per second.
fn bench_coalescing(out: &mut Vec<Entry>) {
    let mut m = UpdateMap::new();
    let delta = vec![0.5f32; 32];
    let r = bench("update coalescing: inc x1e5 into 256 rows", 2, 10, || {
        for i in 0..100_000u64 {
            m.inc((0, i % 256), &delta);
        }
        let _ = m.drain_routed(4, |k| (k.1 % 4) as usize);
    });
    r.print_throughput(1e5, "incs");
    out.push((
        "coalescing_inc_1e5_256rows".into(),
        r.mean.as_secs_f64(),
        r.throughput(1e5),
    ));
}

/// Sparse coalescing throughput on an LDA-shaped stream: every INC
/// touches 2 of K=1024 indices and each row keeps the same few indices,
/// so the coalesced delta stays far below the densify threshold — fold
/// cost and flush bytes are O(nnz), not O(K).
fn bench_coalescing_sparse(out: &mut Vec<Entry>) {
    let mut m = UpdateMap::new();
    let r = bench("update coalescing sparse: K=1024, nnz≈2", 2, 10, || {
        for i in 0..100_000u64 {
            let row = i % 256;
            let a = ((row * 31) % 1024) as usize;
            let b = ((row * 131 + 512) % 1024) as usize;
            m.inc_sparse((0, row), 1024, &[(a, 1.0), (b, -1.0)]);
        }
        let _ = m.drain_routed(4, |k| (k.1 % 4) as usize);
    });
    r.print_throughput(1e5, "incs");
    out.push((
        "coalescing_inc_sparse_1e5_k1024_nnz2".into(),
        r.mean.as_secs_f64(),
        r.throughput(1e5),
    ));
}

/// End-to-end GET/INC/CLOCK rate on an instant network (pure PS overhead).
/// `alloc_free` switches the worker loop from `get()` (compat, allocates a
/// Vec per read) to `get_into()` (reusable buffer, allocation-free reads).
fn bench_get_inc_clock(
    consistency: Consistency,
    workers: usize,
    alloc_free: bool,
    out: &mut Vec<Entry>,
) {
    let variant = if alloc_free { "get_into" } else { "get" };
    let label = format!(
        "e2e {} x{workers}w {variant}: 64 rd+inc/clock, 200 clocks",
        consistency.label()
    );
    let r = bench(&label, 1, 5, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency,
            net: NetConfig::instant(),
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                if alloc_free {
                    let mut buf: Vec<f32> = Vec::new();
                    Box::new(move |ps: &mut PsClient, _c: Clock| {
                        for i in 0..64u64 {
                            let key = (0, (w as u64 * 64 + i) % 256);
                            ps.get_into(key, &mut buf);
                            ps.inc(key, &[0.001f32; 32]);
                        }
                        None
                    }) as Box<dyn PsApp>
                } else {
                    Box::new(move |ps: &mut PsClient, _c: Clock| {
                        for i in 0..64u64 {
                            let key = (0, (w as u64 * 64 + i) % 256);
                            let _row = ps.get(key);
                            ps.inc(key, &[0.001f32; 32]);
                        }
                        None
                    }) as Box<dyn PsApp>
                }
            })
            .collect();
        let _ = cluster.run(apps, 200);
    });
    let ops = (workers * 64 * 200) as f64;
    r.print_throughput(ops, "get+inc");
    out.push((
        format!("e2e_{}_x{workers}w_{variant}", consistency.label()),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// The same GET/INC/CLOCK workload over the real loopback-TCP data plane
/// (`tcp_loopback` series): what wire encoding + two socket hops cost per
/// operation, directly comparable to the in-process `e2e_*` numbers.
fn bench_get_inc_clock_tcp(consistency: Consistency, workers: usize, out: &mut Vec<Entry>) {
    let label = format!(
        "e2e {} x{workers}w get_into tcp_loopback: 64 rd+inc/clock, 200 clocks",
        consistency.label()
    );
    let r = bench(&label, 1, 3, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency,
            net: NetConfig::instant(),
            transport: TransportSel::Tcp,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..64u64 {
                        let key = (0, (w as u64 * 64 + i) % 256);
                        ps.get_into(key, &mut buf);
                        ps.inc(key, &[0.001f32; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 200);
    });
    let ops = (workers * 64 * 200) as f64;
    r.print_throughput(ops, "get+inc");
    out.push((
        format!("e2e_{}_x{workers}w_get_into_tcp_loopback", consistency.label()),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// LDA-shaped sparse flushes over the real loopback-TCP data plane: wide
/// rows (K=1024), 2-index INCs. Before the hybrid delta plane every
/// flush shipped all K f32s per touched row; now it ships O(nnz) pairs —
/// this series watches that byte win translate into wall-clock.
fn bench_sparse_flush_tcp(out: &mut Vec<Entry>) {
    let workers = 4;
    let label = "e2e essp:3 x4w sparse-inc tcp_loopback: K=1024, 16 rd+inc2/clock, 100 clocks";
    let r = bench(label, 1, 3, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency: Consistency::Essp { s: 3 },
            net: NetConfig::instant(),
            transport: TransportSel::Tcp,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 64, 1024));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..16u64 {
                        let key = (0, (w as u64 * 16 + i) % 64);
                        ps.get_into(key, &mut buf);
                        let idx = ((w as u64 * 37 + i * 3) % 1024) as usize;
                        ps.inc_sparse(key, &[(idx, 1.0), ((idx + 5) % 1024, -1.0)]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 100);
    });
    let ops = (workers * 16 * 100) as f64;
    r.print_throughput(ops, "get+inc2");
    out.push((
        "e2e_essp3_x4w_sparse_inc_tcp_loopback".into(),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// LDA-shaped eager waves over the real loopback-TCP data plane, with
/// disjoint writer/reader sets so the wire-v7 delta chains actually
/// engage: worker w owns 16 of 64 wide rows (K=1024, sparse inc2) and
/// reads the next worker's partition, so every row has one writer (wave
/// snapshots, read-my-writes) and one pure reader (wave delta chains,
/// O(nnz) per push instead of O(K)). The companion byte-level claim is
/// pinned by the shard-level 8x framed-bytes test; this series watches
/// the wall-clock side of the same win.
fn bench_delta_push_tcp(out: &mut Vec<Entry>) {
    let workers = 4;
    let label = "e2e essp:3 x4w delta-push tcp_loopback: K=1024, 16 wr + 16 rd/clock, 100 clocks";
    let r = bench(label, 1, 3, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency: Consistency::Essp { s: 3 },
            net: NetConfig::instant(),
            transport: TransportSel::Tcp,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 64, 1024));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    let mine = w as u64 * 16;
                    let theirs = ((w + 1) % 4) as u64 * 16;
                    for i in 0..16u64 {
                        let idx = ((w as u64 * 37 + i * 3) % 1024) as usize;
                        ps.inc_sparse((0, mine + i), &[(idx, 1.0), ((idx + 5) % 1024, -1.0)]);
                        ps.get_into((0, theirs + i), &mut buf);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 100);
    });
    let ops = (workers * 32 * 100) as f64;
    r.print_throughput(ops, "inc2+rd");
    out.push((
        "e2e_essp3_x4w_delta_push".into(),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// The vectored frame-batching hot loop in isolation: encode a stream of
/// delta-chain push frames back-to-back into one reusable batch buffer,
/// "flushing" (clearing) at the TCP writer's 64 KiB coalescing boundary —
/// the per-frame cost of the writer's encode+coalesce path with the
/// socket write taken out of the picture.
fn bench_wire_batch_flush(out: &mut Vec<Entry>) {
    use essptable::ps::msg::{PushRow, ToWorker};
    use essptable::ps::types::RowDelta;
    use essptable::transport::{wire, NodeId, Packet};
    const COALESCE: usize = 64 * 1024;
    const FRAMES: u64 = 4096;
    let rows: Vec<PushRow> = (0..8)
        .map(|i| {
            let chain: std::sync::Arc<[RowDelta]> =
                vec![RowDelta::sparse(1024, vec![(3, 1.0), (700, -0.5)])].into();
            PushRow::deltas((0, i), 6, chain, 7)
        })
        .collect();
    let packet = Packet::ToWorker(ToWorker::Push {
        shard: 0,
        vclock: 7,
        rows,
        span: None,
    });
    let mut batch: Vec<u8> = Vec::with_capacity(COALESCE);
    let r = bench("wire batch flush: 4096 delta-push frames, 64 KiB batches", 2, 10, || {
        for _ in 0..FRAMES {
            wire::write_frame(&mut batch, NodeId::Shard(0), NodeId::Worker(1), &packet)
                .expect("encode");
            if batch.len() >= COALESCE {
                batch.clear();
            }
        }
        batch.clear();
    });
    r.print_throughput(FRAMES as f64, "frames");
    out.push((
        "wire_batch_flush".into(),
        r.mean.as_secs_f64(),
        r.throughput(FRAMES as f64),
    ));
}

/// Elastic shard plane: the same logreg workload over 4 provisioned
/// shards with 2 initially active, migrating 2 -> 4 mid-run (grow at
/// clock 100 of 200, deterministic) — what a live rebalance costs in
/// wall-clock versus the static 2-shard baseline series.
fn bench_migration_2to4(out: &mut Vec<Entry>) {
    use essptable::apps::logreg::{run_logreg, LogRegConfig};
    let label = "e2e bsp x4w logreg migration 2->4 shards mid-run (deterministic)";
    let clocks = 200u64;
    let r = bench(label, 1, 3, || {
        let (_report, _) = run_logreg(
            ClusterConfig {
                workers: 4,
                shards: 4,
                active_shards: 2,
                migration: Some(MigrationSpec {
                    at_clock: 100,
                    grow_to: Some(4),
                    moves: vec![],
                }),
                consistency: Consistency::Bsp,
                net: NetConfig::instant(),
                deterministic: true,
                ..Default::default()
            },
            LogRegConfig::default(),
            clocks,
        );
    });
    r.print_throughput(clocks as f64, "clocks");
    out.push((
        "e2e_bsp_x4w_logreg_migration_2to4_mid_run".into(),
        r.mean.as_secs_f64(),
        r.throughput(clocks as f64),
    ));
}

/// Durable-log overhead: the headline ESSP workload with the per-shard
/// WAL enabled under the given fsync policy, directly comparable to the
/// volatile `e2e_essp3_x4w_get_into` series — what crash tolerance costs
/// on the update path (`wal=off` isolates the append/encode cost,
/// `wal=commit` adds one fsync per committed table clock).
fn bench_wal_overhead(fsync: FsyncPolicy, tag: &str, out: &mut Vec<Entry>) {
    let workers = 4;
    let label = format!("e2e essp:3 x{workers}w get_into wal={tag}: 64 rd+inc/clock, 200 clocks");
    let dir = std::env::temp_dir().join(format!("esspt-bench-wal-{}-{tag}", std::process::id()));
    let r = bench(&label, 1, 3, || {
        // Fresh log dir every iteration: leftover generations would put
        // the next run through recovery and skew the measurement.
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = DurabilityConfig::new(&dir);
        cfg.fsync = fsync;
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency: Consistency::Essp { s: 3 },
            net: NetConfig::instant(),
            durability: Some(cfg),
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..64u64 {
                        let key = (0, (w as u64 * 64 + i) % 256);
                        ps.get_into(key, &mut buf);
                        ps.inc(key, &[0.001f32; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 200);
    });
    std::fs::remove_dir_all(&dir).ok();
    let ops = (workers * 64 * 200) as f64;
    r.print_throughput(ops, "get+inc");
    out.push((
        format!("e2e_essp3_x{workers}w_get_into_wal_{tag}"),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// Telemetry-plane overhead: the headline ESSP workload with the full
/// observability stack on — relaxed-atomic registries always record, and
/// this run adds wire-shipped StatsPull polling every 4 clocks plus the
/// event-trace ring — directly comparable to `e2e_essp3_x4w_get_into`.
/// The claim is "out-of-band costs noise, not throughput".
fn bench_telemetry_overhead(out: &mut Vec<Entry>) {
    use essptable::telemetry::trace::TraceRing;
    let workers = 4;
    let label = "e2e essp:3 x4w get_into telemetry-on: 64 rd+inc/clock, 200 clocks";
    let r = bench(label, 1, 5, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency: Consistency::Essp { s: 3 },
            net: NetConfig::instant(),
            stats_pull_every: 4,
            trace: Some(std::sync::Arc::new(TraceRing::new(65536))),
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..64u64 {
                        let key = (0, (w as u64 * 64 + i) % 256);
                        ps.get_into(key, &mut buf);
                        ps.inc(key, &[0.001f32; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 200);
    });
    let ops = (workers * 64 * 200) as f64;
    r.print_throughput(ops, "get+inc");
    out.push((
        "e2e_essp3_x4w_telemetry_on".into(),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// Request-span overhead: the headline ESSP workload with causal
/// tracing armed — every 64th eligible frame carries the 12-byte wire-v9
/// span context and each hop records timed segments into the shared
/// ring — plus the per-shard hot-key sketch. Directly comparable to
/// `e2e_essp3_x4w_get_into`; unsampled frames encode byte-identically
/// to wire v8, so the expected delta is sampling-rate noise.
fn bench_spans_overhead(out: &mut Vec<Entry>) {
    use essptable::telemetry::spans::SpanRing;
    let workers = 4;
    let label = "e2e essp:3 x4w get_into spans-on: 1/64 sampled, 64 rd+inc/clock, 200 clocks";
    let r = bench(label, 1, 5, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency: Consistency::Essp { s: 3 },
            net: NetConfig::instant(),
            spans: Some(std::sync::Arc::new(SpanRing::new(65536))),
            span_sample: 64,
            hot_key_k: 8,
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..64u64 {
                        let key = (0, (w as u64 * 64 + i) % 256);
                        ps.get_into(key, &mut buf);
                        ps.inc(key, &[0.001f32; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 200);
    });
    let ops = (workers * 64 * 200) as f64;
    r.print_throughput(ops, "get+inc");
    out.push((
        "e2e_essp3_x4w_spans_on".into(),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// Self-healing failover end-to-end: the headline ESSP workload with a
/// replicated table losing primary 0 at mid-run — the detector confirms
/// the death, promotes the replica, workers repoint mid-flight, and a
/// fresh spare is caught up behind the attach fence (`re_replicate`).
/// Comparable to `e2e_essp3_x4w_get_into`: the delta is what one full
/// detect→promote→repoint→re-replicate cycle costs a 200-clock run.
/// The measured detection window (victim's last proof of life to
/// promotion) is printed alongside.
fn bench_failover_recovery(out: &mut Vec<Entry>) {
    let workers = 4;
    let clocks = 200u64;
    let label = "e2e essp:3 x4w failover-recovery: kill s0@100, 64 rd+inc/clock, 200 clocks";
    let mut windows: Vec<u64> = Vec::new();
    let r = bench(label, 1, 3, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            replicas: 1,
            consistency: Consistency::Essp { s: 3 },
            net: NetConfig::instant(),
            faults: FaultPlan::parse("kill=s0@100").unwrap(),
            failover: FailoverConfig {
                re_replicate: true,
                ..FailoverConfig::default()
            },
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                let mut buf: Vec<f32> = Vec::new();
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..64u64 {
                        let key = (0, (w as u64 * 64 + i) % 256);
                        ps.get_into(key, &mut buf);
                        ps.inc(key, &[0.001f32; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let rep = cluster.run(apps, clocks);
        if let Some(ms) = rep.failover_ms {
            windows.push(ms);
        }
    });
    let ops = (workers as u64 * 64 * clocks) as f64;
    r.print_throughput(ops, "get+inc");
    if let Some(&ms) = windows.iter().max() {
        println!("    detection->promotion window: <= {ms} ms");
    }
    out.push((
        "e2e_essp3_x4w_failover_recovery".into(),
        r.mean.as_secs_f64(),
        r.throughput(ops),
    ));
}

/// Push (ESSP) vs pull (SSP) refresh traffic for the same workload:
/// message counts + bytes (the batching claim).
fn bench_push_vs_pull_traffic() {
    for consistency in [Consistency::Ssp { s: 1 }, Consistency::Essp { s: 1 }] {
        let mut cluster = Cluster::new(ClusterConfig {
            workers: 4,
            shards: 2,
            consistency,
            net: NetConfig::instant(),
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 512, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..4)
            .map(|w| {
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    // Shared hot set: every worker reads+writes 128 rows.
                    for i in 0..128u64 {
                        let key = (0, (w as u64 * 37 + i * 3) % 512);
                        let _ = ps.get(key);
                        ps.inc(key, &[0.01; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let report = cluster.run(apps, 100);
        println!(
            "{:<44} {:>10} msgs {:>10.1} MB  ({} pull-replies, {} push-rows)",
            format!("refresh traffic {}", consistency.label()),
            report.net_messages,
            report.net_bytes as f64 / 1e6,
            report
                .shard_stats
                .iter()
                .map(|s| s.gets_served)
                .sum::<u64>(),
            report
                .shard_stats
                .iter()
                .map(|s| s.rows_pushed)
                .sum::<u64>(),
        );
    }
}

fn entries_json(entries: &[Entry]) -> Json {
    Json::Arr(
        entries
            .iter()
            .map(|(name, mean_s, per_s)| {
                let mut o = BTreeMap::new();
                o.insert("name".to_string(), Json::Str(name.clone()));
                o.insert("mean_s".to_string(), Json::Num(*mean_s));
                o.insert("per_s".to_string(), Json::Num(*per_s));
                Json::Obj(o)
            })
            .collect(),
    )
}

/// Current git commit, for baseline/current provenance (a baseline
/// accidentally recorded on the wrong commit is then detectable).
fn git_rev() -> Json {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| Json::Str(s.trim().to_string()))
        .unwrap_or(Json::Null)
}

/// A recorded run: `{rev, results: [...]}`.
fn run_json(entries: &[Entry]) -> Json {
    let mut o = BTreeMap::new();
    o.insert("rev".to_string(), git_rev());
    o.insert("results".to_string(), entries_json(entries));
    Json::Obj(o)
}

/// Result rows of a recorded run, tolerating both the `{rev, results}`
/// object form and a bare array (older files).
fn run_results(run: &Json) -> Option<&[Json]> {
    match run {
        Json::Arr(rows) => Some(rows),
        Json::Obj(_) => run.get("results").ok().and_then(|r| r.as_arr().ok()),
        _ => None,
    }
}

/// Write `BENCH_ps_throughput.json`: the fresh run as `current`, keeping
/// the oldest recorded run as `baseline` (first run seeds it), plus
/// per-benchmark `speedup_vs_baseline` ratios.
fn write_json(entries: &[Entry]) {
    let path = std::env::var("ESSPTABLE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_ps_throughput.json".to_string());
    let path = std::path::PathBuf::from(path);
    let prior = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    // Baseline: the prior baseline if recorded, else the prior current
    // (i.e. the first post-change run promotes the pre-change numbers).
    let baseline = prior.as_ref().and_then(|j| {
        j.opt("baseline")
            .ok()
            .flatten()
            .or_else(|| j.opt("current").ok().flatten())
            .cloned()
    });

    let current = run_json(entries);
    let mut speedups = BTreeMap::new();
    if let Some(base) = &baseline {
        if let Some(base_rows) = run_results(base) {
            for (name, _mean, per_s) in entries {
                for row in base_rows {
                    let matches = row
                        .get("name")
                        .ok()
                        .and_then(|n| n.as_str().ok().map(|s| s == name))
                        .unwrap_or(false);
                    if matches {
                        if let Ok(base_per_s) = row.get("per_s").and_then(|v| v.as_f64()) {
                            if base_per_s > 0.0 {
                                speedups.insert(
                                    name.clone(),
                                    Json::Num(per_s / base_per_s),
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("ps_throughput".to_string()));
    root.insert(
        "note".to_string(),
        Json::Str(
            "per_s = operations/second; baseline is preserved from the \
             first recorded run, current overwritten each run by \
             scripts/bench.sh"
                .to_string(),
        ),
    );
    root.insert("baseline".to_string(), baseline.unwrap_or(Json::Null));
    root.insert("current".to_string(), current);
    root.insert("speedup_vs_baseline".to_string(), Json::Obj(speedups));
    match std::fs::write(&path, Json::Obj(root).to_string_pretty(2)) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write {}: {e}", path.display()),
    }
}

fn main() {
    println!("== ps_throughput (paper §ESSPTable system claims) ==");
    // Quick mode (scripts/bench.sh --quick, the CI smoke): the cheap
    // microbenchmarks plus one e2e series — enough to catch a panic or a
    // gross regression in the hot paths without CI-scale runtimes.
    let quick = std::env::var("ESSPTABLE_BENCH_QUICK").is_ok();
    let mut entries = Vec::new();
    bench_coalescing(&mut entries);
    bench_coalescing_sparse(&mut entries);
    bench_wire_batch_flush(&mut entries);
    if quick {
        bench_delta_push_tcp(&mut entries);
        bench_spans_overhead(&mut entries);
        write_json(&entries);
        return;
    }
    for c in [
        Consistency::Bsp,
        Consistency::Ssp { s: 3 },
        Consistency::Essp { s: 3 },
        Consistency::Async { refresh_every: 1 },
    ] {
        bench_get_inc_clock(c, 4, false, &mut entries);
    }
    // The alloc-free read path on the headline ESSP config.
    bench_get_inc_clock(Consistency::Essp { s: 3 }, 4, true, &mut entries);
    // Value-bounded models (per-update waves + ∞-norm reports + bound
    // grants — the policy layer's most message-intensive path).
    bench_get_inc_clock(Consistency::Vap { v0: 1000.0 }, 4, true, &mut entries);
    bench_get_inc_clock(Consistency::Avap { v0: 1000.0, s: 3 }, 4, true, &mut entries);
    // The same workload over real loopback TCP (codec + socket cost).
    bench_get_inc_clock_tcp(Consistency::Bsp, 4, &mut entries);
    bench_get_inc_clock_tcp(Consistency::Essp { s: 3 }, 4, &mut entries);
    // VAP over TCP: possible at all only since the consistency-policy
    // refactor distributed its enforcement onto the wire.
    bench_get_inc_clock_tcp(Consistency::Vap { v0: 1000.0 }, 4, &mut entries);
    // Sparse flushes of wide rows over TCP (the hybrid delta plane win).
    bench_sparse_flush_tcp(&mut entries);
    // Eager waves with pure readers: the wire-v7 delta-chain win.
    bench_delta_push_tcp(&mut entries);
    // Elastic shard plane: a live 2->4 rebalance mid-run.
    bench_migration_2to4(&mut entries);
    // Crash tolerance: the WAL's cost at both ends of the fsync dial,
    // versus the volatile e2e_essp3_x4w_get_into series.
    bench_wal_overhead(FsyncPolicy::Off, "off", &mut entries);
    bench_wal_overhead(FsyncPolicy::Commit, "commit", &mut entries);
    // Observability: wire-shipped stats + tracing vs the bare series.
    bench_telemetry_overhead(&mut entries);
    // Causal request spans + hot-key sketch vs the bare series.
    bench_spans_overhead(&mut entries);
    // Self-healing failover: one detect->promote->repoint cycle mid-run.
    bench_failover_recovery(&mut entries);
    bench_push_vs_pull_traffic();
    write_json(&entries);
}
