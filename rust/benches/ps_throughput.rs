//! PS microbenchmarks — §ESSPTable system claims:
//!   * update coalescing reduces message count and amortizes INC cost,
//!   * server-push batching beats per-request pull on refresh traffic,
//!   * the GET/INC hot path is allocation-light and fast.
//!
//! Run with `cargo bench --bench ps_throughput`.

use essptable::ps::client::PsClient;
use essptable::ps::consistency::Consistency;
use essptable::ps::server::{Cluster, ClusterConfig, PsApp, TableSpec};
use essptable::ps::types::Clock;
use essptable::ps::update::UpdateMap;
use essptable::sim::net::NetConfig;
use essptable::util::benchkit::bench;

/// Raw coalescing throughput: INCs folded per second.
fn bench_coalescing() {
    let mut m = UpdateMap::new();
    let delta = vec![0.5f32; 32];
    let r = bench("update coalescing: inc x1e5 into 256 rows", 2, 10, || {
        for i in 0..100_000u64 {
            m.inc((0, i % 256), &delta);
        }
        let _ = m.drain_routed(4, |k| (k.1 % 4) as usize);
    });
    r.print_throughput(1e5, "incs");
}

/// End-to-end GET/INC/CLOCK rate on an instant network (pure PS overhead).
fn bench_get_inc_clock(consistency: Consistency, workers: usize) {
    let label = format!(
        "e2e {} x{workers}w: 64 get+inc per clock, 200 clocks",
        consistency.label()
    );
    let r = bench(&label, 1, 5, || {
        let mut cluster = Cluster::new(ClusterConfig {
            workers,
            shards: 2,
            consistency,
            net: NetConfig::instant(),
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 256, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..workers)
            .map(|w| {
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    for i in 0..64u64 {
                        let key = (0, (w as u64 * 64 + i) % 256);
                        let _row = ps.get(key);
                        ps.inc(key, &[0.001f32; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let _ = cluster.run(apps, 200);
    });
    let ops = (workers * 64 * 200) as f64;
    r.print_throughput(ops, "get+inc");
}

/// Push (ESSP) vs pull (SSP) refresh traffic for the same workload:
/// message counts + bytes (the batching claim).
fn bench_push_vs_pull_traffic() {
    for consistency in [Consistency::Ssp { s: 1 }, Consistency::Essp { s: 1 }] {
        let mut cluster = Cluster::new(ClusterConfig {
            workers: 4,
            shards: 2,
            consistency,
            net: NetConfig::instant(),
            ..Default::default()
        });
        cluster.add_table(TableSpec::zeros(0, 512, 32));
        let apps: Vec<Box<dyn PsApp>> = (0..4)
            .map(|w| {
                Box::new(move |ps: &mut PsClient, _c: Clock| {
                    // Shared hot set: every worker reads+writes 128 rows.
                    for i in 0..128u64 {
                        let key = (0, (w as u64 * 37 + i * 3) % 512);
                        let _ = ps.get(key);
                        ps.inc(key, &[0.01; 32]);
                    }
                    None
                }) as Box<dyn PsApp>
            })
            .collect();
        let report = cluster.run(apps, 100);
        println!(
            "{:<44} {:>10} msgs {:>10.1} MB  ({} pull-replies, {} push-rows)",
            format!("refresh traffic {}", consistency.label()),
            report.net_messages,
            report.net_bytes as f64 / 1e6,
            report
                .shard_stats
                .iter()
                .map(|s| s.gets_served)
                .sum::<u64>(),
            report
                .shard_stats
                .iter()
                .map(|s| s.rows_pushed)
                .sum::<u64>(),
        );
    }
}

fn main() {
    println!("== ps_throughput (paper §ESSPTable system claims) ==");
    bench_coalescing();
    for c in [
        Consistency::Bsp,
        Consistency::Ssp { s: 3 },
        Consistency::Essp { s: 3 },
        Consistency::Async { refresh_every: 1 },
    ] {
        bench_get_inc_clock(c, 4);
    }
    bench_push_vs_pull_traffic();
}
