//! Fig. 1 regenerator (bench form): staleness distribution (left) and
//! LDA comm/comp breakdown (right), scaled down so `cargo bench` finishes
//! in a couple of minutes. The CLI (`essptable fig1-staleness`,
//! `essptable fig1-breakdown`) runs the full-size versions.
//!
//! Expected shape (paper): SSP differentials ~uniform over the staleness
//! window; ESSP concentrated with smaller mean/variance; ESSP comm time
//! below SSP at every staleness, both decreasing in s.

use std::path::PathBuf;

use essptable::apps::lda::LdaConfig;
use essptable::apps::mf::MfConfig;
use essptable::harness::{self, ExpOpts};
use essptable::sim::straggler::StragglerModel;

fn opts() -> ExpOpts {
    ExpOpts {
        workers: 8,
        shards: 4,
        seed: 42,
        clocks: 30,
        out_dir: PathBuf::from("results/bench"),
        straggler: StragglerModel::RandomUniform { max_factor: 2.0 },
        lan: true,
        transport: Default::default(),
        virtual_clock_ms: 20,
        replicas: 0,
    }
}

fn main() {
    println!("== fig1 (left): staleness distributions, MF s=3 ==");
    let mf = MfConfig {
        rows: 512,
        cols: 512,
        minibatch: 0.5,
        ..Default::default()
    };
    let runs = harness::fig1_staleness(&opts(), mf, 3).expect("fig1 staleness");
    for run in &runs {
        let h = &run.report.staleness;
        println!(
            "{:<8} mean {:+.3} var {:.3} dist {:?}",
            run.label,
            h.mean(),
            h.variance(),
            h.normalized()
                .iter()
                .map(|(d, f)| format!("{d}:{f:.2}"))
                .collect::<Vec<_>>()
        );
    }
    let (ssp, essp) = (&runs[0].report.staleness, &runs[1].report.staleness);
    println!(
        "ESSP variance reduction vs SSP: {:.2}x (paper: concentrated vs near-uniform)",
        ssp.variance() / essp.variance().max(1e-9)
    );

    println!("\n== fig1 (right): LDA comm/comp breakdown ==");
    let lda = LdaConfig {
        docs: 200,
        ..Default::default()
    };
    let rows = harness::fig1_breakdown(
        &ExpOpts {
            workers: 4,
            shards: 2,
            clocks: 15,
            ..opts()
        },
        lda,
        &[0, 2, 8],
    )
    .expect("fig1 breakdown");
    println!("{:<10} {:>9} {:>9} {:>7}", "label", "comp(s)", "comm(s)", "comm%");
    for (label, comp, comm, frac) in rows {
        println!("{label:<10} {comp:>9.2} {comm:>9.2} {:>6.1}%", 100.0 * frac);
    }
}
