//! Fig. 2 regenerator (bench form): convergence per iteration and per
//! second for MF (squared loss) and LDA (log-likelihood) across BSP / SSP
//! / ESSP, scaled down for `cargo bench`. The CLI (`essptable fig2-mf`,
//! `essptable fig2-lda`) runs the full-size versions; §Robustness and
//! §VAP rows are also printed here so one bench run covers the paper's
//! remaining evaluation claims.
//!
//! Expected shape (paper): ESSP >= SSP per iteration and a larger margin
//! per second; staleness helps SSP substantially, ESSP less (already
//! fresh); SSP destabilizes at large step x staleness, ESSP does not; VAP
//! pays read stalls for its value bound.

use std::path::PathBuf;

use essptable::apps::lda::LdaConfig;
use essptable::apps::mf::MfConfig;
use essptable::harness::{self, ExpOpts};
use essptable::sim::straggler::StragglerModel;

fn opts(clocks: u64) -> ExpOpts {
    ExpOpts {
        workers: 8,
        shards: 4,
        seed: 42,
        clocks,
        out_dir: PathBuf::from("results/bench"),
        straggler: StragglerModel::RandomUniform { max_factor: 2.0 },
        lan: true,
        transport: Default::default(),
        virtual_clock_ms: 15,
        replicas: 0,
    }
}

fn mf_cfg() -> MfConfig {
    MfConfig {
        rows: 512,
        cols: 512,
        minibatch: 0.5,
        gamma: 0.04,
        ..Default::default()
    }
}

fn main() {
    println!("== fig2 (MF): squared loss, lower is better ==");
    let runs = harness::fig2_mf(&opts(30), mf_cfg(), &[3]).expect("fig2 mf");
    for r in &runs {
        println!(
            "{:<8} final {:>12.2}  wall {:>6.2}s",
            r.label,
            r.final_value,
            r.report.wall.as_secs_f64()
        );
    }

    println!("\n== fig2 (LDA): log-likelihood, higher is better ==");
    let lda = LdaConfig {
        docs: 200,
        ..Default::default()
    };
    let runs = harness::fig2_lda(
        &ExpOpts {
            workers: 4,
            shards: 2,
            ..opts(20)
        },
        lda,
        &[3],
    )
    .expect("fig2 lda");
    for r in &runs {
        println!(
            "{:<8} final {:>14.1}  wall {:>6.2}s",
            r.label,
            r.final_value,
            r.report.wall.as_secs_f64()
        );
    }

    println!("\n== robustness: step size x staleness (diverged flags) ==");
    let rows = harness::robustness(
        &ExpOpts {
            workers: 4,
            shards: 2,
            virtual_clock_ms: 0,
            lan: false,
            straggler: StragglerModel::None,
            ..opts(30)
        },
        MfConfig {
            rows: 256,
            cols: 256,
            minibatch: 1.0,
            ..mf_cfg()
        },
        &[0.05, 0.15],
        &[0, 5],
    )
    .expect("robustness");
    for r in rows {
        println!(
            "{:<8} gamma {:<5} final {:>12.2} diverged {}",
            r.label, r.gamma, r.final_loss, r.diverged
        );
    }

    println!("\n== vap: value-bound stall cost vs essp ==");
    let rows = harness::vap_compare(
        &ExpOpts {
            workers: 4,
            shards: 2,
            virtual_clock_ms: 5,
            ..opts(20)
        },
        MfConfig {
            rows: 256,
            cols: 256,
            minibatch: 1.0,
            ..mf_cfg()
        },
        &[0.5, 0.05],
        3,
    )
    .expect("vap compare");
    for r in rows {
        println!(
            "{:<10} wall {:>6.2}s  final {:>10.2}  stall {:>6.2}s over {} reads",
            r.label,
            r.wall.as_secs_f64(),
            r.final_loss,
            r.stall.as_secs_f64(),
            r.stalled_reads
        );
    }
}
