#!/usr/bin/env python3
"""Render the paper's figures from the CSVs the experiment harness emits.

Usage:  python scripts/plot_figures.py [results-dir] [out-dir]

Reads fig1_staleness.csv / fig1_breakdown.csv / fig2_mf.csv / fig2_lda.csv
(whichever exist) and writes PNGs mirroring the paper's panels: staleness
histograms (Fig 1 left), stacked comm/comp bars (Fig 1 right), and
convergence vs iteration & vs seconds (Fig 2). Requires matplotlib (plot
generation is optional tooling; the CSVs are the primary artifact).
"""

import csv
import sys
from collections import defaultdict
from pathlib import Path

try:
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt
except ImportError:
    sys.exit("matplotlib not available; the CSVs under results/ are the data")


def load(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def fig1_staleness(results, out):
    path = results / "fig1_staleness.csv"
    if not path.exists():
        return
    rows = load(path)
    series = defaultdict(list)
    for r in rows:
        series[r["label"]].append((int(r["differential"]), float(r["fraction"])))
    fig, ax = plt.subplots(figsize=(6, 4))
    width = 0.8 / max(len(series), 1)
    for i, (label, pts) in enumerate(sorted(series.items())):
        pts.sort()
        xs = [d + i * width for d, _ in pts]
        ax.bar(xs, [f for _, f in pts], width=width, label=label)
    ax.set_xlabel("clock differential (parameter age − local clock)")
    ax.set_ylabel("fraction of reads")
    ax.set_title("Fig 1 (left): empirical staleness distribution (MF)")
    ax.legend()
    fig.tight_layout()
    fig.savefig(out / "fig1_staleness.png", dpi=120)
    print(f"wrote {out}/fig1_staleness.png")


def fig1_breakdown(results, out):
    path = results / "fig1_breakdown.csv"
    if not path.exists():
        return
    rows = load(path)
    labels = [r["label"] for r in rows]
    comp = [float(r["comp_seconds"]) for r in rows]
    comm = [float(r["comm_seconds"]) for r in rows]
    fig, ax = plt.subplots(figsize=(7, 4))
    xs = range(len(labels))
    ax.bar(xs, comp, label="computation")
    ax.bar(xs, comm, bottom=comp, label="communication")
    ax.set_xticks(list(xs))
    ax.set_xticklabels(labels, rotation=45, ha="right")
    ax.set_ylabel("seconds (summed over workers)")
    ax.set_title("Fig 1 (right): comm/comp breakdown (LDA)")
    ax.legend()
    fig.tight_layout()
    fig.savefig(out / "fig1_breakdown.png", dpi=120)
    print(f"wrote {out}/fig1_breakdown.png")


def fig2(results, out, name, ylabel):
    path = results / f"{name}.csv"
    if not path.exists():
        return
    rows = load(path)
    series = defaultdict(list)
    for r in rows:
        series[r["label"]].append(
            (int(r["clock"]), float(r["seconds"]), float(r["value"]))
        )
    fig, axes = plt.subplots(1, 2, figsize=(11, 4))
    for label, pts in sorted(series.items()):
        pts.sort()
        axes[0].plot([c for c, _, _ in pts], [v for _, _, v in pts], label=label)
        axes[1].plot([s for _, s, _ in pts], [v for _, _, v in pts], label=label)
    axes[0].set_xlabel("clock (iteration)")
    axes[1].set_xlabel("seconds")
    for ax in axes:
        ax.set_ylabel(ylabel)
        ax.legend()
    fig.suptitle(f"Fig 2: {name} convergence per iteration and per second")
    fig.tight_layout()
    fig.savefig(out / f"{name}.png", dpi=120)
    print(f"wrote {out}/{name}.png")


def main():
    results = Path(sys.argv[1] if len(sys.argv) > 1 else "results/final")
    out = Path(sys.argv[2] if len(sys.argv) > 2 else results)
    out.mkdir(parents=True, exist_ok=True)
    fig1_staleness(results, out)
    fig1_breakdown(results, out)
    fig2(results, out, "fig2_mf", "training squared loss")
    fig2(results, out, "fig2_lda", "log-likelihood")


if __name__ == "__main__":
    main()
