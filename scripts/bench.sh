#!/usr/bin/env bash
# Run the PS throughput benchmark and record results in
# BENCH_ps_throughput.json at the repo root.
#
# The bench binary itself performs the JSON bookkeeping: the fresh run is
# written as "current", the oldest recorded run is preserved as
# "baseline" (the first run seeds it), and per-benchmark
# speedup_vs_baseline ratios are computed. Running this script once
# before and once after a perf change therefore records both numbers —
# the cross-PR perf ratchet.
#
# Series recorded: in-process e2e_* numbers (SimNet data plane), the
# e2e_*_tcp_loopback series — the same workload over the real TCP
# transport (wire codec + socket hops), for the sim-vs-real comparison —
# e2e_essp3_x4w_telemetry_on, the headline workload with wire-shipped
# stats polling + event tracing enabled, vs its bare get_into twin — and
# e2e_essp3_x4w_spans_on, the same workload with wire-v9 causal request
# spans sampled 1/64 plus the hot-key sketch (the profiling plane's
# overhead series).
#
# Usage: scripts/bench.sh [--quick]
#
# --quick runs the smoke subset (microbenchmarks, one e2e series, and
# the spans-on series): what CI executes to catch panics and gross
# hot-path regressions without full-bench runtimes. The JSON bookkeeping
# is identical.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
export ESSPTABLE_BENCH_JSON="$ROOT/BENCH_ps_throughput.json"

if [[ "${1:-}" == "--quick" ]]; then
  export ESSPTABLE_BENCH_QUICK=1
fi

cd "$ROOT"
cargo bench --bench ps_throughput

echo
echo "recorded -> $ESSPTABLE_BENCH_JSON"
